//! The serving engine: ConServe's event loop.
//!
//! One loop drives both deployment modes — wall-clock serving on the
//! PJRT backend and discrete-event simulation on the cost-model backend:
//!
//! ```text
//! loop:
//!   drain arrivals -> priority queues
//!   complete async swap I/O (checkpoints, prefetches)
//!   steal tick (sharded+steal only) -> adopt/donate migrated offline work
//!   schedule (Algorithm 1)  -> iteration plan + preemption decisions
//!   execute with safepoints -> Algorithm 2 may abort pure-offline batches
//!   commit results          -> tokens, metrics, KV accounting
//!   checkpoint tick         -> adaptive incremental checkpointing (§4.4)
//!   issue prefetches        -> background swap-in within the I/O budget
//!   store flush tick        -> durable JobStore snapshots every K iters
//!   urgency restamp tick    -> recompute queued-offline laxity scores
//! ```
//!
//! The loop is allocation-free in steady state: requests live in a slab
//! arena ([`RequestArena`]) whose slots the KV manager shares, the
//! [`ScheduleOutcome`] and every I/O / candidate list are persistent
//! buffers reused across iterations, and observability goes through the
//! lock-free trace ring ([`crate::trace`], attached via
//! [`ServingEngine::set_tracer`] — a handful of relaxed atomic stores
//! per event, nothing when detached). See `rust/PERF.md`.
//!
//! One engine serves one worker shard. Multi-worker deployments run N
//! engines ([`ServingEngine::for_shard`]) behind the routing layer in
//! [`crate::shard`]; the only sharded addition to this loop is an
//! optional once-per-iteration load publish (three relaxed atomic
//! stores).

pub mod admission;
pub mod api;
pub mod http;

use crate::backend::{ExecBackend, ExecOutcome, IterationPlan, SafepointAction};
use crate::batch::{FinishedOutput, JobBoard, JobStore};
use crate::clock::Clock;
use crate::config::EngineConfig;
use crate::kvcache::{BlockId, CkptController, Direction, KvManager, SwapEngine, SwapOp};
use crate::metrics::Recorder;
use crate::profiler::LatencyProfile;
use crate::request::{Class, KvResidence, PortableRequest, RequestArena, RequestId, State, TokenId};
use crate::scheduler::harvest::{HarvestConfig, HarvestController, Rule as HarvestRule};
use crate::scheduler::{budget, preempt, Ctx, Policy, ScheduleOutcome, UnifiedScheduler};
use crate::shard::steal::{MigratedRequest, StealCoordinator};
use crate::shard::ShardLoads;
use crate::trace::{prometheus, prometheus::ShardStats as LiveShardStats, EventKind, ShardTracer};
use crate::util::fault::FaultInjector;
use crate::TimeUs;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

pub use api::{ArrivalSource, BatchHandle, EngineClient, SubmitError, SUBMIT_CHANNEL_CAP};

/// Per-token observer (streaming API sink).
pub type TokenCallback = Box<dyn FnMut(RequestId, TokenId, TimeUs)>;

/// Engine-side lifecycle event for live submissions, keyed by the
/// *submission ticket* (`sid`, [`Request::submitted_id`](crate::request::Request::submitted_id))
/// rather than the arena id — arena slots are recycled at commit time
/// when finished requests are reaped, so the ticket is the only key a
/// frontend can correlate on. The front door ([`http`]) consumes these
/// to feed per-connection token streams and completion bookkeeping.
#[derive(Debug, Clone)]
pub enum StreamEvent {
    /// One sampled token (emitted only when the backend produces token
    /// data, e.g. with synth tokens on).
    Token {
        sid: u64,
        class: Class,
        token: TokenId,
        at: TimeUs,
    },
    /// The request finished; carries the full output because the arena
    /// slot may already be recycled when the consumer looks.
    Done {
        sid: u64,
        class: Class,
        job: u64,
        generated: u64,
        output: Vec<TokenId>,
        at: TimeUs,
    },
    /// The request was cancelled before completion (client disconnect).
    Aborted { sid: u64, class: Class, at: TimeUs },
}

/// Stream-event sink (see [`ServingEngine::set_stream_sink`]).
pub type StreamSink = Box<dyn FnMut(StreamEvent)>;

/// Trace event code for a request class (`a`/`b` payload convention:
/// `Online = 0`, `Offline = 1` everywhere a class rides in a trace word).
#[inline]
fn class_code(c: Class) -> u64 {
    match c {
        Class::Online => 0,
        Class::Offline => 1,
    }
}

/// Pack two counters into one trace payload word (`hi << 32 | lo`),
/// saturating each half at `u32::MAX` so a pathological value cannot
/// bleed into the other half.
#[inline]
fn pack2(hi: u64, lo: u64) -> u64 {
    (hi.min(u32::MAX as u64) << 32) | lo.min(u32::MAX as u64)
}

pub struct ServingEngine<B: ExecBackend> {
    pub cfg: EngineConfig,
    pub backend: B,
    pub clock: Clock,
    pub sched: UnifiedScheduler,
    /// Live requests, keyed by slab id. Finished requests stay resident
    /// by default (post-run inspection); see [`set_retain_finished`].
    ///
    /// [`set_retain_finished`]: Self::set_retain_finished
    pub table: RequestArena,
    pub kv: KvManager,
    pub swap: SwapEngine,
    pub ckpt: CkptController,
    pub profile: LatencyProfile,
    pub rec: Recorder,
    arrivals: ArrivalSource,
    on_token: Option<TokenCallback>,
    /// Last iteration's estimate (drives the I/O budget of §4.5).
    last_iter_est_us: u64,
    /// This shard's lock-free flight-recorder ring
    /// ([`set_tracer`](Self::set_tracer)): every decision point emits a
    /// compact event (a few relaxed atomic stores). `None` — and
    /// zero-cost — when tracing is off.
    tracer: Option<Arc<ShardTracer>>,
    /// Live metrics mirror for the Prometheus `/metrics` endpoint
    /// ([`set_live_stats`](Self::set_live_stats)): counters publish every
    /// iteration, quantiles/tenants every
    /// [`prometheus::QUANTILE_EVERY`] iterations.
    live: Option<Arc<LiveShardStats>>,
    /// Prefix blocks reclaimed as of the last loop pass — the engine
    /// emits one `PrefixReclaim` event per positive delta.
    last_prefix_reclaims: u64,
    /// When false, finished requests are removed from the arena at
    /// commit time and their slots recycled — flat memory on
    /// million-request traces.
    retain_finished: bool,
    /// Requests currently in `Prefetching` residence (maintained from
    /// [`ScheduleOutcome::prefetch_started`] + pruning), so the prefetch
    /// pass touches only the handful of restoring requests instead of
    /// scanning the whole arena each iteration.
    prefetch_watch: Vec<RequestId>,
    /// Shared load board for sharded deployments: when set, the loop
    /// publishes this shard's load once per iteration (a few relaxed
    /// atomic stores — no lock on the hot path).
    loads: Option<Arc<ShardLoads>>,
    /// Cross-shard work-stealing coordinator: when set, the loop runs
    /// one steal tick per iteration — adopt deliveries, fulfill demands
    /// within the per-iteration budget, post hunger (see
    /// [`crate::shard::steal`]).
    steal: Option<Arc<StealCoordinator>>,
    /// Shared batch-job progress board ([`crate::batch`]): when set, the
    /// commit path notifies it once per finished job-tagged request (the
    /// poll-able surface behind [`api::BatchHandle`] and the job
    /// manager's deadline attainment).
    job_board: Option<Arc<JobBoard>>,
    /// Decaying recent-thief counter (1/16ths): +16 per adopted steal,
    /// x7/8 per load publish. Published as
    /// [`LoadSnapshot::steal_score`](crate::shard::placement::LoadSnapshot::steal_score)
    /// so placement can bias fresh offline work toward recent thieves.
    steal_heat: u64,
    /// Deterministic fault injection ([`crate::util::fault`]): consulted
    /// at fixed points of the run loop (kill at iteration N, delayed
    /// polls, dropped deliveries, torn store writes). `None` — and
    /// zero-cost — outside fault-injected runs.
    fault: Option<FaultInjector>,
    /// Durable checkpoint sink: when set, job-tagged offline progress
    /// flushes as cold [`PortableRequest`] snapshots (and finished
    /// outputs) every `ckpt_every` iterations, so a crash loses at most
    /// one flush interval of decode progress.
    ckpt_sink: Option<Arc<Mutex<JobStore>>>,
    ckpt_every: u64,
    /// Live lifecycle sink ([`set_stream_sink`](Self::set_stream_sink)):
    /// the front door's bridge from commit-time events to per-connection
    /// token streams. `None` — and zero-cost — outside HTTP serving.
    stream_sink: Option<StreamSink>,
    /// Graceful-drain request ([`set_drain_flag`](Self::set_drain_flag)):
    /// once raised, the run loop exits as soon as no unfinished online
    /// work remains, leaving offline work for
    /// [`drain_to_store`](Self::drain_to_store).
    drain_flag: Option<Arc<AtomicBool>>,
    /// Cancellation inbox ([`set_cancel_queue`](Self::set_cancel_queue)):
    /// submission tickets whose client disconnected. Drained once per
    /// iteration.
    cancel_queue: Option<Arc<Mutex<Vec<u64>>>>,
    /// Cancellations not yet matched to an arena slot (the submission
    /// may still be in the channel), with a retry TTL.
    cancel_pending: Vec<(u64, u8)>,
    /// Run [`JobBoard::gc_completed`] every N iterations (0 = never) so
    /// a long-lived server's board stays bounded.
    gc_jobs_every: u64,
    /// sid -> decode progress at its last flush (`usize::MAX` once the
    /// finished output is recorded) — bounds write amplification to one
    /// line per request per interval, and only on progress.
    flushed: BTreeMap<u64, usize>,
    /// Recompute queued-offline urgency on this virtual-time interval
    /// (0 = off).
    restamp_every_us: TimeUs,
    restamp_svc_tok_per_s: f64,
    next_restamp_at: TimeUs,
    /// Closed-loop harvest controller ([`crate::scheduler::harvest`]):
    /// when `cfg.sched.harvest` is on, one tick per iteration retunes
    /// the scheduler's live offline token budget (`max_batch_tokens`)
    /// and offline prefill chunk (`offline_chunk`) from windowed online
    /// TTFT/TPOT percentiles. The engine's own `cfg` clone stays
    /// pristine — only the scheduler's working copy is actuated.
    harvest: Option<HarvestController>,
    // ---- persistent scratch (reused every iteration) ----
    io_scratch: Vec<SwapOp>,
    ids_scratch: Vec<RequestId>,
    blk_scratch: Vec<usize>,
    pf_scratch: Vec<(usize, BlockId)>,
    mig_scratch: Vec<MigratedRequest>,
    donate_scratch: Vec<MigratedRequest>,
    demand_scratch: Vec<(usize, usize)>,
}

impl<B: ExecBackend> ServingEngine<B> {
    /// Single-worker engine (shard 0).
    pub fn new(
        cfg: EngineConfig,
        backend: B,
        clock: Clock,
        profile: LatencyProfile,
        arrivals: ArrivalSource,
    ) -> Self {
        Self::for_shard(0, cfg, backend, clock, profile, arrivals)
    }

    /// Engine for worker shard `shard` of a sharded deployment: its
    /// arena and KV manager stamp (and check) the shard index in every
    /// id they issue, so this engine's ids can never resolve against a
    /// sibling shard. See [`crate::shard`].
    pub fn for_shard(
        shard: usize,
        cfg: EngineConfig,
        backend: B,
        clock: Clock,
        profile: LatencyProfile,
        arrivals: ArrivalSource,
    ) -> Self {
        let swap = SwapEngine::new(backend.block_bytes(), backend.link_bandwidth());
        let mut kv = KvManager::for_shard(
            shard,
            cfg.mem.gpu_blocks,
            cfg.mem.host_blocks,
            cfg.mem.block_tokens,
        );
        if cfg.sched.prefix_cache {
            kv.enable_prefix_cache();
        }
        let ckpt = CkptController::new(cfg.sched.ckpt_free_watermark, 64);
        // Safe-start: a fresh engine's controller begins at the tight
        // end of the clamp and actuates the scheduler's working config
        // before the first iteration. Crash recovery constructs a fresh
        // engine, so a recovered shard automatically resumes harvesting
        // from the safe initial budget, not the dead shard's last one.
        let harvest = cfg
            .sched
            .harvest
            .then(|| HarvestController::new(HarvestConfig::from_sched(&cfg.sched)));
        let mut sched_cfg = cfg.sched.clone();
        if let Some(h) = &harvest {
            sched_cfg.max_batch_tokens = h.budget();
            sched_cfg.offline_chunk = h.chunk();
        }
        Self {
            sched: UnifiedScheduler::new(sched_cfg),
            cfg,
            backend,
            clock,
            table: RequestArena::for_shard(shard),
            kv,
            swap,
            ckpt,
            profile,
            rec: Recorder::new(),
            arrivals,
            on_token: None,
            last_iter_est_us: 10_000,
            tracer: None,
            live: None,
            last_prefix_reclaims: 0,
            retain_finished: true,
            prefetch_watch: Vec::new(),
            loads: None,
            steal: None,
            job_board: None,
            steal_heat: 0,
            fault: None,
            ckpt_sink: None,
            ckpt_every: 0,
            stream_sink: None,
            drain_flag: None,
            cancel_queue: None,
            cancel_pending: Vec::new(),
            gc_jobs_every: 0,
            flushed: BTreeMap::new(),
            restamp_every_us: 0,
            restamp_svc_tok_per_s: 0.0,
            next_restamp_at: 0,
            harvest,
            io_scratch: Vec::new(),
            ids_scratch: Vec::new(),
            blk_scratch: Vec::new(),
            pf_scratch: Vec::new(),
            mig_scratch: Vec::new(),
            donate_scratch: Vec::new(),
            demand_scratch: Vec::new(),
        }
    }

    pub fn set_token_callback(&mut self, cb: TokenCallback) {
        self.on_token = Some(cb);
    }

    /// Attach the shared load board of a sharded deployment. The run
    /// loop publishes (resident KV blocks, online-reserved blocks,
    /// waiting requests, offline backlog) for this engine's shard once
    /// per iteration.
    pub fn set_shard_loads(&mut self, loads: Arc<ShardLoads>) {
        self.loads = Some(loads);
    }

    /// Attach this shard's flight-recorder ring
    /// ([`crate::trace::ShardTracer`], usually
    /// `fleet.shard(self.shard())` of a
    /// [`FleetTracer`](crate::trace::FleetTracer)). Every decision point
    /// of the loop then emits a compact binary event — admission to the
    /// queues, prefill chunks, per-iteration plan + est/actual latency,
    /// preemptions, steals, checkpoints, harvest retunes, prefix
    /// attach/publish/reclaim, death and recovery. Timestamps come from
    /// this engine's [`Clock`], so simulated traces are deterministic.
    pub fn set_tracer(&mut self, tracer: Arc<ShardTracer>) {
        self.tracer = Some(tracer);
    }

    /// Attach the live metrics cell this engine publishes its
    /// [`Recorder`] aggregates into (the Prometheus `/metrics` surface,
    /// [`crate::trace::prometheus::MetricsHub`]). Counter publishes are
    /// ~20 relaxed stores per iteration; quantile/tenant publishes run
    /// every [`prometheus::QUANTILE_EVERY`] iterations.
    pub fn set_live_stats(&mut self, cell: Arc<LiveShardStats>) {
        self.live = Some(cell);
    }

    /// Emit one trace event if a tracer is attached (no-op otherwise).
    #[inline]
    fn emit(&self, t: TimeUs, kind: EventKind, sid: u64, a: u64, b: u64) {
        if let Some(tr) = &self.tracer {
            tr.emit(t, kind, sid, a, b);
        }
    }

    /// Attach the fleet's work-stealing coordinator
    /// ([`crate::shard::steal`]). Requires a load board
    /// ([`set_shard_loads`](Self::set_shard_loads)) so donors are
    /// discoverable; the run loop then performs one steal tick per
    /// iteration.
    pub fn set_steal_coordinator(&mut self, steal: Arc<StealCoordinator>) {
        self.steal = Some(steal);
    }

    /// Attach a batch-job progress board ([`crate::batch::JobBoard`]).
    /// The commit path then notifies it for every finished request with
    /// a nonzero [`Request::job`](crate::request::Request::job), which
    /// drives poll-able [`api::BatchHandle`] progress and job-level
    /// deadline attainment. For the live channel path, attach the
    /// board the [`EngineClient`] carries:
    /// `engine.set_job_board(client.job_board().clone())`.
    pub fn set_job_board(&mut self, board: Arc<JobBoard>) {
        self.job_board = Some(board);
    }

    /// The closed-loop harvest controller, when enabled
    /// (`cfg.sched.harvest`). Tests and reports read the audit trail
    /// and live budget through this; `None` when the static budget
    /// applies.
    pub fn harvest_controller(&self) -> Option<&HarvestController> {
        self.harvest.as_ref()
    }

    /// True when this engine has no admitted work left and its arrival
    /// source is exhausted — the run loop's natural exit condition.
    /// Fleet drivers use this to tell "out of local work" (idle-wait for
    /// steals) from "stopped on the time cap".
    pub fn drained(&self) -> bool {
        self.arrivals.exhausted() && !self.sched.has_work(&self.table)
    }

    /// The worker shard this engine serves (0 for single-worker).
    pub fn shard(&self) -> usize {
        self.table.shard()
    }

    /// Keep (default) or reap finished requests. With `false`, a
    /// finished request's arena slot and KV registration are recycled at
    /// commit time — required for flat-memory million-request runs; its
    /// per-request fields are no longer inspectable afterwards (metrics
    /// aggregates capture everything the reports need).
    pub fn set_retain_finished(&mut self, retain: bool) {
        self.retain_finished = retain;
    }

    /// Arm deterministic fault injection for this shard (built from a
    /// [`FaultPlan`](crate::util::fault::FaultPlan) via
    /// [`injector_for`](crate::util::fault::FaultPlan::injector_for)).
    /// The run loop consults it at fixed points: kill at the top of an
    /// iteration (outside every lock), delayed steal polls, dropped
    /// steal deliveries, and one torn store write.
    pub fn set_fault_injector(&mut self, f: FaultInjector) {
        self.fault = Some(f);
    }

    /// Attach a durable checkpoint sink: every `every` engine
    /// iterations the engine flushes cold snapshots of in-progress
    /// job-tagged requests (and the outputs of newly finished ones) to
    /// `store`. A crash then loses at most one flush interval of decode
    /// progress — recovery resumes from the newest checkpoint, and
    /// keyed sampling makes the re-decoded stream byte-identical.
    pub fn set_ckpt_sink(&mut self, store: Arc<Mutex<JobStore>>, every: u64) {
        self.ckpt_sink = Some(store);
        self.ckpt_every = every.max(1);
    }

    /// Re-stamp queued offline urgency every `every_us` of virtual time
    /// (service rate `svc_tok_per_s`), so a request whose deadline
    /// laxity eroded while it sat queued climbs the admission order
    /// instead of keeping its stale arrival-time score.
    pub fn set_urgency_restamp(&mut self, every_us: TimeUs, svc_tok_per_s: f64) {
        self.restamp_every_us = every_us;
        self.restamp_svc_tok_per_s = svc_tok_per_s;
        self.next_restamp_at = every_us;
    }

    /// Attach a lifecycle sink: the commit path emits a
    /// [`StreamEvent`] per sampled token and per completion, and the
    /// cancellation path per abort. The front door uses this to feed
    /// chunked token streams and to account completions without keeping
    /// finished requests resident.
    pub fn set_stream_sink(&mut self, sink: StreamSink) {
        self.stream_sink = Some(sink);
    }

    /// Attach a shared graceful-drain flag. Once raised (by the front
    /// door after it stopped accepting), the run loop keeps iterating
    /// until every admitted *online* request has finished, then breaks —
    /// offline work still in flight is left for
    /// [`drain_to_store`](Self::drain_to_store) to checkpoint.
    pub fn set_drain_flag(&mut self, flag: Arc<AtomicBool>) {
        self.drain_flag = Some(flag);
    }

    /// Attach a cancellation inbox of submission tickets (client
    /// disconnects). Each iteration the engine resolves queued tickets:
    /// waiting requests are removed and their KV freed immediately;
    /// running ones are clamped to finish at the next sampled token
    /// (their slot and KV then free through the normal commit path).
    pub fn set_cancel_queue(&mut self, queue: Arc<Mutex<Vec<u64>>>) {
        self.cancel_queue = Some(queue);
    }

    /// Garbage-collect completed jobs from the attached [`JobBoard`]
    /// every `every` iterations (0 disables). Long-running serve loops
    /// enable this so the board does not grow by one entry per completed
    /// batch forever; trace-driven experiment runs leave it off because
    /// they read the board's completed cells for end-of-run reports.
    pub fn set_job_gc(&mut self, every: u64) {
        self.gc_jobs_every = every;
    }

    /// Run until `until` (µs) has passed *and* all admitted work is done,
    /// or all sources are exhausted. Returns the finish time.
    pub fn run(&mut self, until: TimeUs) -> TimeUs {
        // The ScheduleOutcome (plan + victim lists) lives across
        // iterations so its buffers recycle their capacity.
        let mut out = ScheduleOutcome::default();
        loop {
            let now = self.clock.now();
            self.rec.engine_iters += 1;
            if let Some(f) = &self.fault {
                if f.should_kill(self.rec.engine_iters) {
                    // the flight recorder's last word: the supervisor's
                    // ShardDied payload carries this same iteration, so
                    // post-mortem dumps and supervision agree on where
                    // the shard stopped
                    self.emit(now, EventKind::ShardDeath, 0, self.rec.engine_iters, 0);
                    // outside every lock: an injected death can never
                    // poison shared state (inboxes, the store mutex)
                    panic!(
                        "{}: shard {} at iteration {}",
                        crate::util::fault::INJECTED_PANIC_MARKER,
                        self.table.shard(),
                        self.rec.engine_iters
                    );
                }
            }
            if now >= until {
                break; // hard experiment stop
            }
            self.drain_arrivals(now);
            self.complete_io(now);
            if self.steal.is_some() {
                self.steal_tick();
            }
            if self.cancel_queue.is_some() || !self.cancel_pending.is_empty() {
                self.cancel_tick(now);
            }
            if self.gc_jobs_every > 0 && self.rec.engine_iters % self.gc_jobs_every == 0 {
                if let Some(board) = &self.job_board {
                    board.gc_completed();
                }
            }
            if let Some(flag) = &self.drain_flag {
                // the front door raises this only after it stopped
                // accepting and its last submission reached the channel,
                // so the arrival drain above has made every accepted
                // online request visible — finish them, then exit and
                // let drain_to_store checkpoint the offline remainder
                if flag.load(Ordering::Acquire) {
                    let online_left = self.table.values().any(|r| {
                        r.class == Class::Online
                            && r.state != State::Finished
                            && r.state != State::Aborted
                    });
                    if !online_left {
                        break;
                    }
                }
            }

            let more_arrivals = !self.arrivals.exhausted();
            let has_work = self.sched.has_work(&self.table);
            if !has_work && !more_arrivals {
                break;
            }

            // ---- harvest controller tick (ARCHITECTURE.md §10) ----
            if let Some(h) = self.harvest.as_mut() {
                let waiting = self.sched.online_waiting();
                if let Some(rule) = h.tick(self.rec.engine_iters, now, waiting) {
                    // actuate the scheduler's working config this same
                    // iteration; the audit trail already recorded it
                    self.sched.cfg.max_batch_tokens = h.budget();
                    self.sched.cfg.offline_chunk = h.chunk();
                    self.rec.harvest_decisions += 1;
                    // trace payload: a = audit id (1-based index into
                    // the controller's audit log, which just recorded
                    // this decision), b = the budget permille actuated
                    let audit_id = h.audit_log().len() as u64;
                    let permille = h.budget_permille();
                    match rule {
                        HarvestRule::Tighten => {
                            self.rec.harvest_tightens += 1;
                            self.emit(now, EventKind::HarvestTighten, 0, audit_id, permille);
                        }
                        HarvestRule::Open => {
                            self.rec.harvest_opens += 1;
                            self.emit(now, EventKind::HarvestOpen, 0, audit_id, permille);
                        }
                        HarvestRule::Hold => {}
                    }
                }
            }

            // ---- schedule (Algorithm 1) ----
            {
                let mut ctx = Ctx {
                    table: &mut self.table,
                    kv: &mut self.kv,
                    profile: &self.profile,
                    now,
                    max_model_len: self.cfg.max_model_len,
                };
                self.sched.schedule(&mut ctx, &mut out);
            }
            // prefix-sharing accounting: admission-time attach results
            // from this schedule pass, plus the shared-residency peak
            self.rec.prefix_hits += out.prefix_hits;
            self.rec.prefill_tokens_skipped += out.prefill_tokens_skipped;
            self.rec.shared_block_residency = self
                .rec
                .shared_block_residency
                .max(self.kv.shared_gpu_blocks() as u64);
            if out.prefix_hits > 0 {
                self.emit(
                    now,
                    EventKind::PrefixAttach,
                    0,
                    out.prefix_hits,
                    out.prefill_tokens_skipped,
                );
            }
            if self.kv.prefix_enabled() {
                let reclaimed = self.kv.prefix_reclaimed_blocks();
                if reclaimed > self.last_prefix_reclaims {
                    self.emit(
                        now,
                        EventKind::PrefixReclaim,
                        0,
                        reclaimed - self.last_prefix_reclaims,
                        0,
                    );
                    self.last_prefix_reclaims = reclaimed;
                }
            }
            if let Some(loads) = &self.loads {
                loads.publish(
                    self.table.shard(),
                    (self.kv.gpu_total() - self.kv.gpu_free()) as u64,
                    self.sched.reserved_online_blocks() as u64,
                    (self.sched.online_waiting() + self.sched.offline_waiting()) as u64,
                    self.sched.offline_waiting() as u64,
                    self.steal_heat,
                );
                // decay the recent-thief signal once per publish (x7/8
                // reaches zero, unlike h - h/8 which floors at 1)
                self.steal_heat = self.steal_heat * 7 / 8;
                if let Some(h) = &self.harvest {
                    loads.publish_budget(self.table.shard(), h.budget_permille());
                }
                if self.kv.prefix_enabled() {
                    let (hits, lookups) = self.kv.prefix_stats();
                    let digest = self.kv.prefix_digest();
                    loads.publish_prefix(self.table.shard(), hits, lookups, &digest);
                }
            }
            if let Some(cell) = &self.live {
                // live Prometheus mirror: counters every iteration (a
                // batch of relaxed stores), quantiles and per-tenant
                // counters on a coarser cadence (they walk histogram
                // buckets / take a mutex)
                cell.publish_counters(&self.rec);
                if self.rec.engine_iters % prometheus::QUANTILE_EVERY == 0 {
                    cell.publish_quantiles(&self.rec);
                    cell.publish_tenants(&self.rec);
                }
            }

            self.apply_victims(&out, now);

            if out.plan.items.is_empty() {
                // memory management must continue while idle — resumes
                // blocked on prefetch would otherwise deadlock the queue
                self.checkpoint_tick();
                self.prefetch_tick();
                self.store_flush_tick();
                self.restamp_tick();
                self.idle_advance(until);
                continue;
            }

            // ---- execute with safepoints (Algorithm 2) ----
            let sched_at = self.clock.now();
            let summary = out.plan.summary();
            let est = self.profile.estimate_us(&summary);
            self.last_iter_est_us = est.max(1_000);
            let outcome = self.execute_plan(&out.plan, sched_at, est);
            let done_at = self.clock.now();

            match outcome {
                Ok(o) if o.completed => {
                    // plan shape + estimated-vs-actual latency; the
                    // Perfetto exporter unpacks this into a duration
                    // slice per iteration
                    self.emit(
                        done_at,
                        EventKind::Iteration,
                        0,
                        pack2(summary.prefill_tokens as u64, summary.decode_seqs as u64),
                        pack2(est, done_at.saturating_sub(sched_at)),
                    );
                    self.commit(&out.plan, &o);
                }
                Ok(_aborted) => {
                    self.rec.layer_aborts += 1;
                    self.emit(done_at, EventKind::LayerAbort, 0, summary.prefill_tokens as u64, 0);
                    // nothing committed; scheduler re-plans next loop with
                    // the online arrivals now visible
                }
                Err(e) => panic!("backend execution failed: {e:?}"),
            }

            // ---- post-iteration memory management (§4.4/§4.5) ----
            self.checkpoint_tick();
            self.prefetch_tick();
            self.store_flush_tick();
            self.restamp_tick();
        }
        self.clock.now()
    }

    /// Apply backend/data effects of the scheduler's preemption and
    /// blocking-swap decisions.
    fn apply_victims(&mut self, out: &ScheduleOutcome, now: TimeUs) {
        // dedup on insert: under sustained pressure the same request can
        // be demoted to Host (prefetch cancel) and re-flipped to
        // Prefetching every iteration — blind extends would grow the
        // watch list by one stale copy per iteration for the whole
        // pressure episode. The list is small (restoring requests), so a
        // linear containment check is cheaper than any set.
        for &id in &out.prefetch_started {
            if !self.prefetch_watch.contains(&id) {
                self.prefetch_watch.push(id);
            }
        }
        // Preempt trace payload: a = mode (0 discard/recompute,
        // 1 evict-to-checkpoint, 2 blocking swap-out)
        for &id in &out.discarded {
            let sid = self.table.get(id).map(|r| r.submitted_id).unwrap_or(0);
            self.emit(now, EventKind::Preempt, sid, 0, 0);
            self.backend.drop_request(id);
            self.swap.drop_request(id);
            self.rec.preemptions += 1;
        }
        for &id in &out.evicted {
            let sid = self.table.get(id).map(|r| r.submitted_id).unwrap_or(0);
            self.emit(now, EventKind::Preempt, sid, 1, 0);
            self.rec.preemptions += 1;
            // data already mirrored by incremental checkpoints; free
            // the device copy (prefetch will restore it)
            self.backend.evict_device(id);
        }
        for &id in &out.swapped_out {
            // blocking D2H of every resident block (vLLM++ path)
            let sid = self.table.get(id).map(|r| r.submitted_id).unwrap_or(0);
            self.emit(now, EventKind::Preempt, sid, 2, 0);
            let seq_tokens = self.kv.seq(id).map(|s| s.tokens).unwrap_or(0);
            let blocks = seq_tokens.div_ceil(self.kv.block_tokens);
            for b in 0..blocks {
                self.backend.copy_block_d2h(id, b, self.kv.block_tokens);
            }
            self.backend.evict_device(id);
            self.rec.preemptions += 1;
        }
        for &id in &out.swapped_in {
            let seq_tokens = self.kv.seq(id).map(|s| s.tokens).unwrap_or(0);
            let blocks = seq_tokens.div_ceil(self.kv.block_tokens);
            for b in 0..blocks {
                self.backend.copy_block_h2d(id, b, self.kv.block_tokens);
            }
        }
        if out.blocking_io_blocks > 0 {
            // blocking transfers stall the pipeline (Fig. 4b)
            let us = self.swap.blocking_transfer_us(
                now,
                Direction::D2H,
                out.blocking_io_blocks,
            );
            self.clock.advance(us);
            self.rec.blocking_swap_us += us;
        }
    }

    fn execute_plan(
        &mut self,
        plan: &IterationPlan,
        sched_at: TimeUs,
        est_us: u64,
    ) -> anyhow::Result<ExecOutcome> {
        // Split borrows for the safepoint closure.
        let arrivals = &mut self.arrivals;
        let sched = &mut self.sched;
        let table = &mut self.table;
        let profile = &self.profile;
        let tracer = self.tracer.clone();
        let slo_us = (self.cfg.sched.slo.ttft_ms * 1000.0) as u64;
        let chunk = self.cfg.sched.chunk_size;
        let layerwise = self.cfg.sched.layerwise_preempt;

        let mut cb = |now: TimeUs| -> SafepointAction {
            // arrivals become visible at safepoints (§4.3)
            arrivals.poll_each(now, &mut |req| {
                let class = req.class;
                if let Some(tr) = &tracer {
                    tr.emit(
                        now,
                        EventKind::QueueEnter,
                        req.submitted_id,
                        class_code(class),
                        req.prompt_len as u64,
                    );
                }
                let id = table.insert(req);
                sched.enqueue(id, class);
            });
            if !layerwise || sched.online_waiting() == 0 {
                return SafepointAction::Continue;
            }
            let q = preempt::PreemptQuery {
                now,
                oldest_online_arrival: sched.oldest_online_arrival(table).unwrap_or(now),
                batch_sched_at: sched_at,
                batch_est_us: est_us,
                online_shape: sched.online_queue_shape(table, chunk),
                ttft_slo_us: slo_us,
            };
            if preempt::should_preempt(profile, &q) {
                SafepointAction::Abort
            } else {
                SafepointAction::Continue
            }
        };
        self.backend.execute(plan, &mut cb)
    }

    fn commit(&mut self, plan: &IterationPlan, o: &ExecOutcome) {
        let now = self.clock.now();
        for (i, item) in plan.items.iter().enumerate() {
            let Some(r) = self.table.get_mut(item.req) else {
                continue;
            };
            self.kv
                .commit(item.req, item.n_tokens)
                .expect("scheduled item without grown blocks");
            if item.n_tokens > 1 {
                // a prefill chunk (decode commits exactly one token);
                // b carries the context length *before* this chunk
                if let Some(tr) = &self.tracer {
                    tr.emit(
                        now,
                        EventKind::PrefillChunk,
                        r.submitted_id,
                        item.n_tokens as u64,
                        r.ctx_len as u64,
                    );
                }
            }
            r.ctx_len += item.n_tokens;
            if self.kv.prefix_enabled() && r.ctx_len <= r.prompt_len {
                // prefill progress committed whole prompt blocks: index
                // them so later prompts with this prefix can attach
                self.kv.prefix_publish(item.req, &r.prompt);
                if let Some(tr) = &self.tracer {
                    tr.emit(
                        now,
                        EventKind::PrefixPublish,
                        r.submitted_id,
                        0,
                        r.ctx_len as u64,
                    );
                }
            }
            self.rec.record_processed(now, item.class, item.n_tokens);

            if r.ctx_len == r.feed_target() {
                // a new token was sampled by this iteration's head
                r.generated += 1;
                // the simulator returns no token data (empty vec)
                let tok = o.new_tokens.get(i).copied().flatten();
                if let Some(t) = tok {
                    r.output.push(t);
                }
                let class = r.class;
                if r.generated == 1 {
                    r.first_token_at = Some(now);
                    let ttft = now.saturating_sub(r.arrival);
                    self.rec.record_first_token(now, class, ttft);
                    if let Some(tr) = &self.tracer {
                        tr.emit(
                            now,
                            EventKind::FirstToken,
                            r.submitted_id,
                            ttft,
                            class_code(class),
                        );
                    }
                    // harvest controller observes *online* latency only:
                    // offline latency is the thing being traded away
                    if class == Class::Online {
                        if let Some(h) = self.harvest.as_mut() {
                            h.observe_ttft(ttft);
                        }
                    }
                } else {
                    let last = r.last_token_at.unwrap_or(now);
                    let gap = now.saturating_sub(last);
                    self.rec.record_token(now, class, gap);
                    if class == Class::Online {
                        if let Some(h) = self.harvest.as_mut() {
                            h.observe_tpot(gap);
                        }
                    }
                }
                r.last_token_at = Some(now);
                let done = r.is_done();
                let (job, tenant, deadline, gen) =
                    (r.job, r.tenant, r.deadline, r.generated as u64);
                let sid = r.submitted_id;
                // the Done event carries the whole output: when finished
                // requests are reaped the slot is recycled before any
                // consumer could read it back out of the arena
                let done_output = if done
                    && (self.stream_sink.is_some()
                        || (job != 0 && self.ckpt_sink.is_some()))
                {
                    r.output.clone()
                } else {
                    Vec::new()
                };
                if done {
                    r.state = State::Finished;
                    r.finished_at = Some(now);
                }
                // flush a finished job member's output to the durable
                // store now: with finished requests reaped at commit
                // time this is the last point that still holds the
                // output, and a restart must not re-run completed work
                if done && job != 0 {
                    if let Some(sink) = self.ckpt_sink.clone() {
                        let f = FinishedOutput {
                            sid,
                            job,
                            generated: gen,
                            output: done_output.clone(),
                        };
                        if sink.lock().unwrap().record_output(&f).is_ok() {
                            self.flushed.insert(sid, usize::MAX);
                            self.rec.ckpt_flush_records += 1;
                        }
                    }
                }
                if let (Some(cb), Some(t)) = (self.on_token.as_mut(), tok) {
                    cb(item.req, t, now);
                }
                if let Some(sink) = self.stream_sink.as_mut() {
                    if done {
                        sink(StreamEvent::Done {
                            sid,
                            class,
                            job,
                            generated: gen,
                            output: done_output,
                            at: now,
                        });
                    } else if let Some(t) = tok {
                        sink(StreamEvent::Token {
                            sid,
                            class,
                            token: t,
                            at: now,
                        });
                    }
                }
                if done {
                    self.emit(now, EventKind::Finish, sid, class_code(class), gen);
                    self.rec.record_finished(class);
                    if job != 0 || deadline > 0 {
                        self.note_job_finish(job, tenant, deadline, gen, now);
                    }
                    self.kv.release(item.req, false);
                    self.backend.drop_request(item.req);
                    self.swap.drop_request(item.req);
                    if !self.retain_finished {
                        self.table.remove(item.req);
                    }
                }
            }
        }
    }

    /// Deadline + job bookkeeping for one finished request (off the
    /// token hot path — runs once per request completion). Per-request
    /// deadline attainment and per-tenant counters land in the
    /// [`Recorder`]; the shared [`JobBoard`] (if attached) learns the
    /// completion and reports job-level attainment when the last request
    /// of a job finishes.
    fn note_job_finish(
        &mut self,
        job: u64,
        tenant: u32,
        deadline: TimeUs,
        gen_tokens: u64,
        now: TimeUs,
    ) {
        let met = if deadline > 0 { Some(now <= deadline) } else { None };
        match met {
            Some(true) => self.rec.deadline_met += 1,
            Some(false) => self.rec.deadline_missed += 1,
            None => {}
        }
        if job == 0 {
            return;
        }
        self.rec.note_tenant_finished(tenant, gen_tokens, met);
        if let Some(board) = &self.job_board {
            if let Some(completed) = board.note_finished(job, gen_tokens, now) {
                self.rec.jobs_completed += 1;
                if completed.deadline > 0 {
                    if completed.met {
                        self.rec.jobs_deadline_met += 1;
                    } else {
                        self.rec.jobs_deadline_missed += 1;
                    }
                }
            }
        }
    }

    /// Resolve client cancellations (see
    /// [`set_cancel_queue`](Self::set_cancel_queue)). A ticket that does
    /// not match an arena slot yet (the submission may still be sitting
    /// in the channel) is retried for a bounded number of iterations,
    /// then dropped — the worst case is one fully-served request nobody
    /// reads, never a leak.
    fn cancel_tick(&mut self, now: TimeUs) {
        if let Some(q) = &self.cancel_queue {
            let mut q = q.lock().unwrap();
            for sid in q.drain(..) {
                self.cancel_pending.push((sid, 16));
            }
        }
        if self.cancel_pending.is_empty() {
            return;
        }
        let mut pending = std::mem::take(&mut self.cancel_pending);
        pending.retain_mut(|(sid, ttl)| {
            let found = self
                .table
                .iter()
                .find(|(_, r)| r.submitted_id == *sid)
                .map(|(id, r)| (id, r.state, r.class));
            let Some((id, state, class)) = found else {
                *ttl = ttl.saturating_sub(1);
                return *ttl > 0; // not visible yet: retry next iteration
            };
            match state {
                State::Finished | State::Aborted => {}
                _ => {
                    if self.sched.remove_online(id) || self.sched.remove_offline(id) {
                        // still queued: abort outright, free slot + KV now
                        self.kv.release(id, false);
                        self.backend.drop_request(id);
                        self.swap.drop_request(id);
                        self.table.remove(id);
                        self.rec.cancelled += 1;
                        if let Some(tr) = &self.tracer {
                            tr.emit(now, EventKind::Abort, *sid, class_code(class), 0);
                        }
                        if let Some(sink) = self.stream_sink.as_mut() {
                            sink(StreamEvent::Aborted {
                                sid: *sid,
                                class,
                                at: now,
                            });
                        }
                    } else if let Some(r) = self.table.get_mut(id) {
                        // admitted (running or preempted): clamp so the
                        // next sampled token finishes it — slot and KV
                        // then free through the normal commit path
                        r.max_new_tokens = r.generated.max(1);
                    }
                }
            }
            false
        });
        self.cancel_pending = pending;
    }

    /// Flush every job-tagged request to the durable store
    /// unconditionally (the graceful-drain final pass): a
    /// [`FinishedOutput`] for each finished request whose output was not
    /// yet recorded, and a cold checkpoint for each unfinished request
    /// with decode progress. Zero-progress members need no record — the
    /// job's spec line already covers them, and keyed sampling makes the
    /// post-resume stream byte-identical either way. Returns
    /// `(outputs, checkpoints)` written. Call after [`run`](Self::run)
    /// breaks on the drain flag.
    pub fn drain_to_store(&mut self) -> (u64, u64) {
        let Some(sink) = self.ckpt_sink.clone() else {
            return (0, 0);
        };
        let now = self.clock.now();
        let mut store = sink.lock().unwrap();
        let (mut outs, mut ckpts) = (0u64, 0u64);
        for r in self.table.values() {
            if r.job == 0 {
                continue;
            }
            match r.state {
                State::Aborted => continue,
                State::Finished => {
                    if self.flushed.get(&r.submitted_id) != Some(&usize::MAX) {
                        let f = FinishedOutput {
                            sid: r.submitted_id,
                            job: r.job,
                            generated: r.generated as u64,
                            output: r.output.clone(),
                        };
                        if store.record_output(&f).is_ok() {
                            self.flushed.insert(r.submitted_id, usize::MAX);
                            self.rec.ckpt_flush_records += 1;
                            outs += 1;
                        }
                    }
                }
                _ => {
                    if r.generated == 0 || self.flushed.get(&r.submitted_id) == Some(&r.generated) {
                        continue;
                    }
                    let p = PortableRequest::snapshot_cold(r);
                    if store.record_checkpoint(&p).is_ok() {
                        self.flushed.insert(r.submitted_id, r.generated);
                        self.rec.ckpt_flush_records += 1;
                        ckpts += 1;
                        // terminal for this shard's span: the request
                        // leaves the arena world as a cold checkpoint
                        if let Some(tr) = &self.tracer {
                            tr.emit(
                                now,
                                EventKind::Drain,
                                r.submitted_id,
                                r.generated as u64,
                                0,
                            );
                        }
                    }
                }
            }
        }
        (outs, ckpts)
    }

    /// Adaptive incremental checkpointing (§4.4): quota from the RED-style
    /// controller, newest-progress offline sequences first; online
    /// sequences join under severe pressure.
    fn checkpoint_tick(&mut self) {
        if !self.cfg.sched.incremental_ckpt || self.cfg.sched.policy != Policy::ConServe {
            return;
        }
        let free = self.kv.gpu_free_frac();
        let quota = self.ckpt.step(free);
        if quota == 0 {
            return;
        }
        let severe = free < self.cfg.sched.ckpt_free_watermark * 0.5;
        let now = self.clock.now();

        // offline candidates first (running order), online only under
        // severe pressure — two passes instead of a sort
        let mut ids = std::mem::take(&mut self.ids_scratch);
        ids.clear();
        let eligible = |r: &crate::request::Request, class: Class| {
            r.residence == KvResidence::Gpu && r.class == class
        };
        ids.extend(self.sched.running_ids().iter().copied().filter(|&id| {
            self.table.get(id).is_some_and(|r| eligible(r, Class::Offline))
        }));
        if severe {
            ids.extend(self.sched.running_ids().iter().copied().filter(|&id| {
                self.table.get(id).is_some_and(|r| eligible(r, Class::Online))
            }));
        }

        let mut blks = std::mem::take(&mut self.blk_scratch);
        let mut issued = 0;
        'outer: for &id in &ids {
            self.kv.checkpoint_candidates_into(id, &mut blks);
            for &idx in &blks {
                if issued >= quota {
                    break 'outer;
                }
                if self.kv.begin_ckpt(id, idx).is_err() {
                    break 'outer; // host pool exhausted
                }
                // data moves now (host<->host on this testbed); the
                // accounting completes on PCIe-modelled time
                self.backend.copy_block_d2h(id, idx, self.kv.block_tokens);
                self.swap.enqueue(now, id, idx, Direction::D2H);
                issued += 1;
            }
        }
        self.rec.ckpt_blocks += issued as u64;
        self.ids_scratch = ids;
        self.blk_scratch = blks;
    }

    /// Background prefetching (§4.4): restore host-resident offline
    /// requests within the per-iteration I/O budget so swap-in overlaps
    /// the next batches' compute.
    fn prefetch_tick(&mut self) {
        if !self.cfg.sched.prefetch || self.cfg.sched.policy != Policy::ConServe {
            return;
        }
        // prune entries that left Prefetching (restored, repaired,
        // cancelled or finished) since the last tick
        let table = &self.table;
        self.prefetch_watch
            .retain(|&id| table.get(id).is_some_and(|r| r.residence == KvResidence::Prefetching));
        if self.prefetch_watch.is_empty() {
            return;
        }
        let io_budget = budget::io_budget(
            self.last_iter_est_us,
            self.swap.block_transfer_us(),
            64,
        );
        if io_budget == 0 {
            return;
        }
        // never prefetch into a pressured pool: restored blocks are
        // pinned (not evictable) until the request runs, so prefetching
        // under pressure steals memory from the online class. Worse, a
        // fleet of half-restored requests can pin the pool with nothing
        // runnable — so under pressure, *cancel* the largest in-progress
        // restore (host checkpoints survive; it reverts to Host).
        let reserve = (self.kv.gpu_total() / 20).max(1);
        if self.kv.gpu_free() <= reserve {
            let mut victim: Option<(usize, RequestId)> = None;
            for &id in &self.prefetch_watch {
                let blocks = self.kv.seq(id).map(|s| s.gpu_blocks()).unwrap_or(0);
                let cand = (blocks, id);
                if victim.is_none_or(|v| cand > v) {
                    victim = Some(cand);
                }
            }
            if let Some((_, id)) = victim {
                self.swap.drop_request(id);
                self.kv.evict_gpu(id);
                self.backend.evict_device(id);
                if let Some(r) = self.table.get_mut(id) {
                    r.residence = KvResidence::Host;
                }
            }
            return;
        }
        let now = self.clock.now();
        let mut ids = std::mem::take(&mut self.ids_scratch);
        ids.clear();
        ids.extend_from_slice(&self.prefetch_watch);
        let mut cands = std::mem::take(&mut self.pf_scratch);
        let mut issued = 0;
        'outer: for &id in &ids {
            if issued >= io_budget {
                break;
            }
            // state-machine repair: a Prefetching request with no
            // outstanding work is either fully restored (flip to Gpu) or
            // has lost host copies (discard to recompute) — either way it
            // must not linger and block the FIFO queue
            if self.kv.missing_prefetch(id) == 0
                && self.swap.inflight_for(id, Direction::H2D) == 0
            {
                let bt = self.kv.block_tokens;
                let resident = self
                    .kv
                    .seq(id)
                    .is_some_and(|s| s.gpu_blocks() >= s.tokens.div_ceil(bt));
                let tokens = self.kv.seq(id).map(|s| s.tokens).unwrap_or(0);
                let r = self.table.get_mut(id).unwrap();
                if resident {
                    r.residence = KvResidence::Gpu;
                } else {
                    // prefetch holes (lost host copies): discard to the
                    // recompute path rather than linger in the queue
                    if let Some(tr) = &self.tracer {
                        tr.emit(now, EventKind::Repair, r.submitted_id, tokens as u64, 0);
                    }
                    r.discard_to_recompute();
                    self.kv.discard(id);
                    self.backend.drop_request(id);
                }
                continue;
            }
            self.kv.prefetch_candidates_into(id, &mut cands);
            for ci in 0..cands.len() {
                let (idx, _hb) = cands[ci];
                if issued >= io_budget {
                    break;
                }
                if self.swap.inflight_for(id, Direction::H2D) + issued >= io_budget {
                    break;
                }
                if self.kv.begin_prefetch(id, idx).is_err() {
                    // GPU pool full. Offline waits; a *latency-critical*
                    // resume must not — discard it to the recompute path
                    // (prefill needs no pinned restore memory up front).
                    if self.table.get(id).is_some_and(|r| r.class == Class::Online) {
                        self.swap.drop_request(id);
                        self.kv.discard(id);
                        self.backend.drop_request(id);
                        self.table.get_mut(id).unwrap().discard_to_recompute();
                    }
                    break 'outer;
                }
                self.swap.enqueue(now, id, idx, Direction::H2D);
                issued += 1;
            }
        }
        self.rec.prefetch_blocks += issued as u64;
        self.ids_scratch = ids;
        self.pf_scratch = cands;
    }

    /// Periodic durable flush to the attached [`JobStore`] (see
    /// [`set_ckpt_sink`](Self::set_ckpt_sink)): every `ckpt_every`
    /// iterations, write a cold [`PortableRequest`] snapshot for each
    /// in-progress job-tagged request that made decode progress since
    /// its last flush, and a durable [`FinishedOutput`] record for each
    /// newly finished one. Write amplification is bounded: at most one
    /// line per request per interval, and only on progress (`flushed`
    /// tracks the generated count at the last flush; `usize::MAX` marks
    /// a recorded output). A crash therefore loses at most one interval
    /// of progress, and replaying from the newest checkpoint reproduces
    /// byte-identical streams via keyed sampling.
    fn store_flush_tick(&mut self) {
        let Some(sink) = self.ckpt_sink.clone() else {
            return;
        };
        if self.ckpt_every == 0 || self.rec.engine_iters % self.ckpt_every != 0 {
            return;
        }
        // one-shot injected torn write: consumed only when a checkpoint
        // record is actually about to be written, so a flush tick with
        // nothing to say cannot silently eat the armed fault
        let flushed_before = self.rec.ckpt_flush_records;
        let mut store = sink.lock().unwrap();
        for r in self.table.values() {
            if r.job == 0 {
                continue;
            }
            match r.state {
                State::Aborted => continue,
                State::Finished => {
                    if self.flushed.get(&r.submitted_id) != Some(&usize::MAX) {
                        let f = FinishedOutput {
                            sid: r.submitted_id,
                            job: r.job,
                            generated: r.generated as u64,
                            output: r.output.clone(),
                        };
                        if store.record_output(&f).is_ok() {
                            self.flushed.insert(r.submitted_id, usize::MAX);
                            self.rec.ckpt_flush_records += 1;
                        }
                    }
                }
                _ => {
                    if r.generated == 0 || self.flushed.get(&r.submitted_id) == Some(&r.generated) {
                        continue;
                    }
                    let p = PortableRequest::snapshot_cold(r);
                    let torn = self.fault.as_mut().is_some_and(|f| f.take_torn());
                    let res = if torn {
                        store.record_checkpoint_torn(&p)
                    } else {
                        store.record_checkpoint(&p)
                    };
                    if res.is_ok() {
                        self.flushed.insert(r.submitted_id, r.generated);
                        self.rec.ckpt_flush_records += 1;
                    }
                }
            }
        }
        let wrote = self.rec.ckpt_flush_records - flushed_before;
        if wrote > 0 {
            self.emit(self.clock.now(), EventKind::CkptFlush, 0, wrote, 0);
        }
    }

    /// Periodic urgency re-stamp (see
    /// [`set_urgency_restamp`](Self::set_urgency_restamp)): recompute
    /// the deadline-laxity urgency of every *queued* offline request at
    /// the current virtual time. The scheduler reads `urgency` live out
    /// of the arena on every admission decision, so updating the field
    /// in place is the whole job — no queue surgery. Running requests
    /// keep their stamp (they are already past admission), and
    /// best-effort work (deadline 0) is never stamped.
    fn restamp_tick(&mut self) {
        if self.restamp_every_us == 0 {
            return;
        }
        let now = self.clock.now();
        if now < self.next_restamp_at {
            return;
        }
        self.next_restamp_at = now + self.restamp_every_us;
        let mut ids = std::mem::take(&mut self.ids_scratch);
        ids.clear();
        ids.extend(self.sched.offline_queue_rev());
        for &id in &ids {
            let Some(r) = self.table.get_mut(id) else { continue };
            if r.deadline == 0 {
                continue;
            }
            let remaining = (r.prompt_len + r.max_new_tokens).saturating_sub(r.generated) as u64;
            let u = crate::batch::urgency_score(
                r.deadline,
                now,
                remaining,
                self.restamp_svc_tok_per_s,
            );
            if u != r.urgency {
                r.urgency = u;
                self.rec.urgency_restamps += 1;
            }
        }
        self.ids_scratch = ids;
    }

    /// Complete async swap ops whose modelled time has passed.
    fn complete_io(&mut self, now: TimeUs) {
        if self.swap.is_idle() {
            return;
        }
        let mut ops = std::mem::take(&mut self.io_scratch);
        self.swap.tick_into(now, &mut ops);
        for op in ops.drain(..) {
            match op.dir {
                Direction::D2H => {
                    self.kv.finish_ckpt(op.req, op.block_idx);
                }
                Direction::H2D => {
                    self.backend
                        .copy_block_h2d(op.req, op.block_idx, self.kv.block_tokens);
                    // last block home? request becomes runnable
                    let done = self.kv.missing_prefetch(op.req) == 0
                        && self.swap.inflight_for(op.req, Direction::H2D) == 0;
                    if done {
                        if let Some(r) = self.table.get_mut(op.req) {
                            if r.residence == KvResidence::Prefetching {
                                r.residence = KvResidence::Gpu;
                            }
                        }
                    }
                }
            }
        }
        self.io_scratch = ops;
    }

    fn drain_arrivals(&mut self, now: TimeUs) {
        let (arrivals, table, sched) = (&mut self.arrivals, &mut self.table, &mut self.sched);
        let tracer = &self.tracer;
        arrivals.poll_each(now, &mut |req| {
            let class = req.class;
            if let Some(tr) = tracer {
                tr.emit(
                    now,
                    EventKind::QueueEnter,
                    req.submitted_id,
                    class_code(class),
                    req.prompt_len as u64,
                );
            }
            let id = table.insert(req);
            sched.enqueue(id, class);
        });
    }

    // ================================================================
    // Cross-shard offline work stealing (crate::shard::steal): one tick
    // per iteration, entirely off the scheduling hot path. The donor
    // half detaches queue-tail victims; the target half re-keys
    // deliveries into this shard's arena.
    // ================================================================

    /// One steal tick: adopt deliveries, fulfill posted demands within
    /// the per-iteration budget, and post this shard's own demand while
    /// its offline backlog is low.
    fn steal_tick(&mut self) {
        let Some(st) = self.steal.clone() else {
            return;
        };
        let shard = self.table.shard();
        // --- target hook: adopt migrations delivered to this shard ---
        self.poll_steals();
        // --- donor hook: fulfill demands within the budget ---
        let mut demands = std::mem::take(&mut self.demand_scratch);
        st.take_demands(shard, &mut demands);
        if !demands.is_empty() {
            let mut budget = st.config().budget_per_iter;
            let keep = st.config().min_donor_backlog;
            let mut out = std::mem::take(&mut self.donate_scratch);
            for &(thief, want) in demands.iter() {
                if budget == 0 {
                    break;
                }
                let surplus = self.sched.offline_waiting().saturating_sub(keep);
                let n = want.min(budget).min(surplus);
                if n == 0 {
                    continue;
                }
                out.clear();
                self.donate_victims(n, &mut out);
                budget = budget.saturating_sub(out.len());
                if !out.is_empty() && self.fault.as_mut().is_some_and(|f| f.drop_delivery()) {
                    // injected lost delivery: the orphan pool keeps the
                    // requests adoptable by any live shard
                    st.divert_to_orphans(&mut out);
                } else {
                    st.deliver(thief, &mut out);
                }
            }
            self.donate_scratch = out;
            demands.clear();
        }
        self.demand_scratch = demands;
        // --- hunger: keep a demand posted while the backlog is low ---
        self.post_hunger();
    }

    /// Drain and adopt any migrations delivered to this shard. Returns
    /// true if anything was absorbed (fleet drivers resume the run loop).
    pub fn poll_steals(&mut self) -> bool {
        let Some(st) = self.steal.clone() else {
            return false;
        };
        if self.fault.as_mut().is_some_and(|f| f.delay_poll()) {
            return false; // injected slow mailbox: defer, never lose
        }
        let mut migs = std::mem::take(&mut self.mig_scratch);
        let n = st.drain_inbox(self.table.shard(), &mut migs);
        if n > 0 {
            self.absorb_migrations(&mut migs);
        }
        self.mig_scratch = migs;
        n > 0
    }

    /// Post (or refresh) this shard's steal demand if its offline
    /// backlog is at or below the hunger watermark. Idempotent.
    pub fn post_hunger(&mut self) {
        let Some(st) = &self.steal else {
            return;
        };
        let shard = self.table.shard();
        if self.sched.offline_waiting() <= st.config().hungry_below {
            if let Some(donor) = st.pick_donor(shard) {
                self.emit(self.clock.now(), EventKind::StealDemand, 0, donor as u64, 0);
                st.post_demand(shard, donor, st.config().budget_per_iter);
            }
        }
    }

    /// Donor hook: extract up to `max` stealable offline requests from
    /// the queue tail into `out`.
    ///
    /// A victim is stealable only when its KV is *free to move*: it
    /// never held any (fresh or discard-preempted — a cold steal), or
    /// every committed token has a completed host checkpoint and no GPU
    /// block or transfer is outstanding (§4.4's evicted state — the
    /// checkpoint accounting and host mirror travel with it). Running
    /// requests, half-restored prefetches, and sequences with in-flight
    /// I/O are never touched, so donating is always a host-side handoff
    /// with zero GPU cost.
    /// Victims leave in urgency order: the donor over-collects (up to
    /// 4x the budget) from the tail, then serves the highest-urgency
    /// candidates first — an urgent deadline job stranded behind a
    /// backlog is exactly the work that should reach an idle shard
    /// soonest. Among equal urgencies the tail-first order is preserved.
    pub fn donate_victims(&mut self, max: usize, out: &mut Vec<MigratedRequest>) {
        if max == 0 {
            return;
        }
        let mut ids = std::mem::take(&mut self.ids_scratch);
        ids.clear();
        for id in self.sched.offline_queue_rev() {
            if ids.len() >= max.saturating_mul(4) {
                break;
            }
            let Some(r) = self.table.get(id) else { continue };
            if r.residence == KvResidence::Prefetching || r.state == State::Running {
                continue;
            }
            if self.swap.inflight_for(id, Direction::D2H) > 0
                || self.swap.inflight_for(id, Direction::H2D) > 0
            {
                continue;
            }
            let portable = match self.kv.seq(id) {
                None => true, // never admitted: no KV anywhere
                Some(s) => {
                    s.gpu_blocks() == 0
                        && (s.tokens == 0 || s.fully_checkpointed(self.kv.block_tokens))
                }
            };
            if portable {
                ids.push(id);
            }
        }
        if ids.len() > 1 && ids.iter().any(|&id| self.table[id].urgency > 0) {
            // stable: equal urgencies keep the tail-first harvest order
            let table = &self.table;
            ids.sort_by_key(|&id| std::cmp::Reverse(table[id].urgency));
        }
        ids.truncate(max);
        for &id in &ids {
            if !self.sched.remove_offline(id) {
                continue;
            }
            let ckpt_tokens = match self.kv.export_host(id) {
                Ok(t) => t,
                Err(_) => {
                    // raced into a non-portable state: put it back
                    self.sched.requeue_preempted(id);
                    continue;
                }
            };
            // data half before teardown: the host mirror moves with the
            // request (sim backends return None — accounting-only)
            let kv_blob = if ckpt_tokens > 0 {
                self.backend.export_host_kv(id)
            } else {
                None
            };
            self.backend.drop_request(id);
            self.swap.drop_request(id);
            let req = self
                .table
                .remove(id)
                .expect("stealable victim must be live in the arena");
            self.rec.steals_out += 1;
            self.rec.stolen_ckpt_tokens += ckpt_tokens as u64;
            // flow start: the thief's StealAbsorb for the same sid closes
            // the arrow across shard tracks in the Perfetto view
            self.emit(
                self.clock.now(),
                EventKind::StealDonate,
                req.submitted_id,
                0,
                ckpt_tokens as u64,
            );
            out.push(MigratedRequest {
                portable: PortableRequest::detach(req, ckpt_tokens),
                kv: kv_blob,
            });
        }
        self.ids_scratch = ids;
    }

    /// Target hook: re-key migrated requests into this shard — fresh
    /// arena id (this shard's bits; the donor id is dead), imported
    /// host-checkpoint prefix, back of the offline queue. A checkpoint
    /// that no longer fits this shard's host pool falls back to the
    /// recompute path (§4.4 extreme case) instead of failing the move.
    ///
    /// Timing caveat (simulation): each shard advances its own virtual
    /// clock, and a migrated request keeps its original `arrival`, so
    /// latency samples recorded here use *this* shard's clock — a thief
    /// whose clock trails the donor's records clamped-to-zero offline
    /// TTFTs, and windowed series bin by local time. Offline latency is
    /// best-effort (never SLO-gated), so reports treat these as
    /// approximate under stealing; online metrics are unaffected
    /// (online work never migrates).
    pub fn absorb_migrations(&mut self, migs: &mut Vec<MigratedRequest>) {
        for m in migs.drain(..) {
            let MigratedRequest { portable, kv } = m;
            let ckpt_tokens = portable.ckpt_tokens;
            let req = portable.into_request();
            // flow end for the donor's StealDonate with the same sid
            self.emit(
                self.clock.now(),
                EventKind::StealAbsorb,
                req.submitted_id,
                0,
                ckpt_tokens as u64,
            );
            let id = self.table.insert(req);
            if ckpt_tokens > 0 {
                match self.kv.import_host(id, ckpt_tokens) {
                    Ok(()) => {
                        if let Some(blob) = kv {
                            self.backend.import_host_kv(id, blob);
                        }
                    }
                    Err(_) => {
                        self.table.get_mut(id).unwrap().discard_to_recompute();
                    }
                }
            } else {
                self.kv.register(id);
            }
            self.sched.enqueue(id, Class::Offline);
            self.rec.steals_in += 1;
            self.steal_heat += 16;
        }
    }

    /// Nothing runnable: jump the virtual clock to the next event, or
    /// nap briefly on the wall clock. With a steal coordinator attached
    /// the jump is capped so an idle shard re-polls its mailbox every
    /// 100 ms of virtual time instead of warping past a whole delivery
    /// window.
    fn idle_advance(&mut self, until: TimeUs) {
        let next_arrival = self.arrivals.next_time();
        let next_io = self.swap.next_completion();
        let target = match (next_arrival, next_io) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        if self.clock.is_virtual() {
            let mut t = match target {
                Some(t) => t.max(self.clock.now() + 1),
                None => until,
            };
            if self.steal.is_some() {
                t = t.min(self.clock.now() + 100_000);
            }
            self.clock.advance_to(t);
        } else {
            self.arrivals.wait_a_moment();
        }
    }
}
