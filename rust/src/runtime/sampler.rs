//! Token sampling from logits: greedy argmax or temperature sampling,
//! deterministic given the engine seed.

use crate::request::TokenId;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct Sampler {
    rng: Rng,
    /// 0.0 => greedy argmax.
    pub temperature: f32,
}

impl Sampler {
    pub fn new(seed: u64, temperature: f32) -> Self {
        Self {
            rng: Rng::new(seed),
            temperature,
        }
    }

    pub fn sample(&mut self, logits: &[f32]) -> TokenId {
        let u = self.rng.f64();
        self.sample_u(logits, u)
    }

    /// Sample with a caller-supplied draw key instead of the sampler's
    /// own RNG stream: the same `(logits, key)` always yields the same
    /// token. The serving path keys each draw by per-request sampler
    /// state and output position
    /// ([`WorkItem::sample_key`](crate::backend::WorkItem::sample_key)),
    /// so token streams are reproducible across chunkings, batch
    /// compositions, and cross-shard migration.
    pub fn sample_keyed(&self, logits: &[f32], key: u64) -> TokenId {
        let u = (crate::util::rng::mix64(key) >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        self.sample_u(logits, u)
    }

    fn sample_u(&self, logits: &[f32], u01: f64) -> TokenId {
        debug_assert!(!logits.is_empty());
        if self.temperature <= 0.0 {
            return argmax(logits);
        }
        // softmax(logits / T) sampling with max-subtraction for stability
        let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut probs: Vec<f32> = logits
            .iter()
            .map(|&l| ((l - max) / self.temperature).exp())
            .collect();
        let sum: f32 = probs.iter().sum();
        if !sum.is_finite() || sum <= 0.0 {
            return argmax(logits);
        }
        for p in &mut probs {
            *p /= sum;
        }
        let mut u = u01 as f32;
        for (i, &p) in probs.iter().enumerate() {
            u -= p;
            if u <= 0.0 {
                return i as TokenId;
            }
        }
        (probs.len() - 1) as TokenId
    }
}

fn argmax(logits: &[f32]) -> TokenId {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as TokenId
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(0, 0.0);
        let logits = vec![0.1, 2.0, -1.0, 1.9];
        assert_eq!(s.sample(&logits), 1);
    }

    #[test]
    fn temperature_sampling_is_distributional() {
        let mut s = Sampler::new(1, 1.0);
        let logits = vec![2.0, 2.0, -10.0];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[s.sample(&logits) as usize] += 1;
        }
        // the two high-logit tokens split the mass; the low one is rare
        assert!(counts[0] > 700 && counts[1] > 700, "{counts:?}");
        assert!(counts[2] < 50, "{counts:?}");
    }

    #[test]
    fn deterministic_per_seed() {
        let logits = vec![0.5, 0.4, 0.3, 0.2];
        let a: Vec<_> = {
            let mut s = Sampler::new(9, 0.8);
            (0..50).map(|_| s.sample(&logits)).collect()
        };
        let b: Vec<_> = {
            let mut s = Sampler::new(9, 0.8);
            (0..50).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn keyed_sampling_is_pure() {
        let s = Sampler::new(0, 0.9);
        let logits = vec![0.5, 0.4, 0.3, 0.2];
        // same key => same token, on any sampler instance
        let t1 = s.sample_keyed(&logits, 0xABCD);
        let t2 = Sampler::new(77, 0.9).sample_keyed(&logits, 0xABCD);
        assert_eq!(t1, t2);
        // distinct keys cover the distribution
        let mut counts = [0usize; 4];
        for k in 0..2000u64 {
            counts[s.sample_keyed(&logits, k) as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c > 100), "{counts:?}");
        // greedy ignores the key entirely
        let g = Sampler::new(0, 0.0);
        assert_eq!(g.sample_keyed(&logits, 1), g.sample_keyed(&logits, 2));
    }

    #[test]
    fn low_temperature_concentrates() {
        let logits = vec![1.0, 1.5];
        let mut s = Sampler::new(2, 0.05);
        let picks: Vec<_> = (0..100).map(|_| s.sample(&logits)).collect();
        assert!(picks.iter().filter(|&&t| t == 1).count() > 95);
    }
}
