//! Request and sequence model.
//!
//! ConServe serves two request classes (paper §2.2): **online** requests
//! arrive through the streaming API and carry TTFT/TPOT SLOs; **offline**
//! requests arrive through the batch API and are best-effort. Internally
//! both flow through the same scheduler as priority levels (§5:
//! "priority queues with two priority levels ... users are not required
//! to manually specify priorities").

pub mod arena;

use crate::util::json::{arr, num, obj, Json};
use crate::TimeUs;

pub use arena::RequestArena;

/// Dense request handle packed as **(generation:32 | shard:8 |
/// slot:24)**, low bits first:
///
/// * bits 0..24 — slab *slot* index into the owning shard's
///   [`RequestArena`] (and its KV manager's sequence table);
/// * bits 24..32 — *shard* index: which worker shard issued the id;
/// * bits 32..64 — the slot's *generation* at insertion time.
///
/// Slot recycling bumps the generation, so a stale id held after its
/// request was removed can never alias the slot's next occupant —
/// lookups with a mismatched generation simply miss. The shard bits make
/// the same guarantee *across* shards: every arena and KV table checks
/// them, so an id from shard A presented to shard B misses even when
/// slot and generation coincide, and routing a ticket back to its owner
/// is a mask+shift ([`rid_shard`]), not a table lookup.
pub type RequestId = u64;

/// Bits of a [`RequestId`] carrying the shard index.
pub const SHARD_BITS: u32 = 8;
/// Bits of a [`RequestId`] carrying the slot index within a shard.
pub const SLOT_BITS: u32 = 24;
/// Maximum number of worker shards addressable by an id (256).
pub const MAX_SHARDS: usize = 1 << SHARD_BITS;
/// Maximum live requests per shard (16M slots, slot 0 reserved).
pub const SLOTS_PER_SHARD: usize = 1 << SLOT_BITS;

const SLOT_MASK: u64 = (1 << SLOT_BITS) - 1;
const SHARD_MASK: u64 = (1 << SHARD_BITS) - 1;

/// Slot index of a request id (dense array key within its shard).
#[inline]
pub fn rid_slot(id: RequestId) -> usize {
    (id & SLOT_MASK) as usize
}

/// Shard index of a request id (which worker shard owns it).
#[inline]
pub fn rid_shard(id: RequestId) -> usize {
    ((id >> SLOT_BITS) & SHARD_MASK) as usize
}

/// Generation counter of a request id.
#[inline]
pub fn rid_gen(id: RequestId) -> u32 {
    (id >> 32) as u32
}

/// Pack a slot + generation into a shard-0 request id (the single-worker
/// engine). See [`rid_pack_sharded`] for the general form.
#[inline]
pub fn rid_pack(slot: usize, generation: u32) -> RequestId {
    rid_pack_sharded(0, slot, generation)
}

/// Pack (shard, slot, generation) into a request id.
#[inline]
pub fn rid_pack_sharded(shard: usize, slot: usize, generation: u32) -> RequestId {
    debug_assert!(shard < MAX_SHARDS, "shard {shard} out of range");
    debug_assert!(slot < SLOTS_PER_SHARD, "slot {slot} out of range");
    ((generation as u64) << 32) | ((shard as u64) << SLOT_BITS) | slot as u64
}

pub type TokenId = u16; // byte-level vocab (256) fits easily

/// Top of the EDF urgency scale carried by [`Request::urgency`]: a job
/// whose estimated remaining work consumes its whole deadline slack (or
/// that is already late) scores `URGENCY_MAX`; a job with no deadline
/// scores 0. See [`crate::batch::JobManager`] for the formula.
pub const URGENCY_MAX: u32 = 1000;

/// Priority class. Ordering: Online > Offline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Class {
    Online,
    Offline,
}

/// Which inference phase the next scheduled tokens of a request belong to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Prefill,
    Decode,
}

/// Where a request's KV state lives when it is not running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvResidence {
    /// All blocks resident on the GPU.
    Gpu,
    /// Preempted; all useful blocks have host checkpoints, GPU copies
    /// freed. Resume = prefetch (swap-in).
    Host,
    /// Preempted; KV discarded. Resume = recompute prefill from token 0.
    Discarded,
    /// Swap-in scheduled/underway; runnable once it completes.
    Prefetching,
}

/// Scheduler-visible request state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum State {
    /// In an arrival queue, never run yet.
    Waiting,
    /// In the running set (may or may not be in the current iteration).
    Running,
    /// Preempted with KV state per `KvResidence`.
    Preempted,
    /// All output tokens generated.
    Finished,
    /// Aborted by the client or the engine.
    Aborted,
}

#[derive(Debug, Clone)]
pub struct Request {
    /// Engine handle: assigned by [`RequestArena::insert`] on admission
    /// (the id passed to [`Request::new`] is provisional).
    pub id: RequestId,
    /// The id this request was *submitted* under (trace id or
    /// [`EngineClient`](crate::server::EngineClient) ticket), preserved
    /// across arena re-keying so callers can correlate results with
    /// submissions.
    pub submitted_id: u64,
    pub class: Class,
    /// Prompt tokens (real path) — empty in pure-simulation experiments.
    pub prompt: Vec<TokenId>,
    /// Prompt length in tokens (== prompt.len() on the real path; the
    /// simulator uses lengths only).
    pub prompt_len: usize,
    /// Number of output tokens to generate (client-requested max).
    pub max_new_tokens: usize,
    pub arrival: TimeUs,

    // ---- mutable serving state ----
    pub state: State,
    pub residence: KvResidence,
    /// Tokens whose KV is committed in the cache (prefill progress +
    /// generated tokens). `ctx_len < prompt_len` means prefill not done.
    pub ctx_len: usize,
    /// Generated output tokens (real path).
    pub output: Vec<TokenId>,
    /// Count of generated tokens (sim path counts without materializing).
    pub generated: usize,
    /// Tokens whose KV blocks have host checkpoints (monotone; paper
    /// §4.4 incremental checkpointing).
    pub ckpt_len: usize,
    pub first_token_at: Option<TimeUs>,
    /// Time the most recent output token was emitted (TPOT bookkeeping —
    /// kept inline so the engine needs no side table on the commit path).
    pub last_token_at: Option<TimeUs>,
    pub finished_at: Option<TimeUs>,
    /// Number of times this request was preempted (any mechanism).
    pub preemptions: u32,
    /// Tokens of prefill recomputed due to discard-preemption (wasted work
    /// accounting, paper Fig. 4a).
    pub recomputed_tokens: usize,
    /// Per-request sampler key seed, derived from the *submitted* id so it
    /// is stable across arena re-keying and cross-shard migration: the
    /// draw for output position `g` is `mix64(sampler_state ^ g)`, making
    /// token streams reproducible regardless of which shard (or chunking)
    /// serves the request.
    pub sampler_state: u64,

    // ---- batch-job identity (crate::batch; all zero for standalone
    // requests, stamped by the JobManager on admission) ----
    /// Owning batch job (0 = not part of a job).
    pub job: u64,
    /// Tenant the owning job bills to (0 = default tenant).
    pub tenant: u32,
    /// EDF-style urgency score in `0..=batch::URGENCY_MAX`, derived from
    /// the job's deadline slack and remaining work at admission. 0 means
    /// no deadline pressure; the fair-share offline pick order and the
    /// steal donor's victim ordering both serve higher urgency first.
    pub urgency: u32,
    /// Weighted fair-share weight of the owning tenant (from the job's
    /// priority tier; 1 = baseline). First admission charges
    /// `total_len * 16 / fair_weight` to the tenant's served account.
    pub fair_weight: u32,
    /// Soft deadline for this request's job (µs timestamp, 0 = none) —
    /// finishing later is allowed but counted as a deadline miss.
    pub deadline: TimeUs,
    /// Scheduler-local flag: this request's footprint has been charged
    /// to its tenant's fair-share account *in the current scheduler*.
    /// Deliberately not portable (resets on migration and durable-store
    /// resume): each shard/process keeps its own accounts, so a request
    /// entering a new account domain must be charged there — while a
    /// locally preempted request re-admitting must not pay twice.
    pub fair_charged: bool,
}

impl Request {
    pub fn new(
        id: RequestId,
        class: Class,
        prompt: Vec<TokenId>,
        prompt_len: usize,
        max_new_tokens: usize,
        arrival: TimeUs,
    ) -> Self {
        debug_assert!(prompt.is_empty() || prompt.len() == prompt_len);
        Self {
            id,
            submitted_id: id,
            class,
            prompt,
            prompt_len,
            max_new_tokens,
            arrival,
            state: State::Waiting,
            residence: KvResidence::Gpu,
            ctx_len: 0,
            output: Vec::new(),
            generated: 0,
            ckpt_len: 0,
            first_token_at: None,
            last_token_at: None,
            finished_at: None,
            preemptions: 0,
            recomputed_tokens: 0,
            sampler_state: crate::util::rng::mix64(id ^ 0x5EED_C0DE),
            job: 0,
            tenant: 0,
            urgency: 0,
            fair_weight: 1,
            deadline: 0,
            fair_charged: false,
        }
    }

    /// Total tokens this request will ever hold in cache.
    pub fn total_len(&self) -> usize {
        self.prompt_len + self.max_new_tokens
    }

    /// Feed target: index up to which known tokens (prompt + generated
    /// outputs) must be fed so the next head sample is a *new* token.
    /// Initially `prompt_len`; grows by one per generated token. After a
    /// discard-preemption (`ctx_len` reset to 0) the gap `target - ctx`
    /// covers the whole recompute (paper Fig. 4a).
    pub fn feed_target(&self) -> usize {
        self.prompt_len + self.generated
    }

    /// Tokens still to feed before the next new token is sampled.
    pub fn remaining_feed(&self) -> usize {
        self.feed_target().saturating_sub(self.ctx_len)
    }

    /// Phase of the *next* scheduled work: a single-token gap is a decode
    /// step; a larger gap is (re)prefill, processed in chunks.
    pub fn phase(&self) -> Phase {
        if self.remaining_feed() > 1 {
            Phase::Prefill
        } else {
            Phase::Decode
        }
    }

    pub fn is_done(&self) -> bool {
        self.generated >= self.max_new_tokens
    }

    /// Concrete token ids for the next `n` feed positions (real path):
    /// prompt tokens then generated outputs.
    pub fn feed_tokens(&self, n: usize) -> Vec<TokenId> {
        let mut out = Vec::with_capacity(n);
        self.feed_tokens_into(n, &mut out);
        out
    }

    /// Append the next `n` feed tokens to `out` without allocating a
    /// per-call vector — the scheduler stages all of an iteration's
    /// token chunks into one reusable plan buffer this way.
    pub fn feed_tokens_into(&self, n: usize, out: &mut Vec<TokenId>) {
        out.extend((self.ctx_len..self.ctx_len + n).map(|i| {
            if i < self.prompt.len() {
                self.prompt[i]
            } else {
                let j = i - self.prompt.len();
                self.output.get(j).copied().unwrap_or(0)
            }
        }));
    }

    /// TTFT if the first token has been emitted.
    pub fn ttft(&self) -> Option<TimeUs> {
        self.first_token_at.map(|t| t.saturating_sub(self.arrival))
    }

    /// Forfeit all committed context to the recompute path (discard
    /// preemption, §4.4 extreme case / Fig. 4a): the next admission
    /// re-prefills from token 0 and the lost work is charged to
    /// `recomputed_tokens`. KV accounting (`KvManager::discard` /
    /// `release`) is the caller's responsibility.
    pub fn discard_to_recompute(&mut self) {
        let lost = self.ctx_len;
        self.ctx_len = 0;
        self.ckpt_len = 0;
        self.recomputed_tokens += lost;
        self.residence = KvResidence::Discarded;
    }
}

/// A request detached from any shard: everything needed to rebuild it in
/// another shard's arena, and nothing tied to the donor (no arena id, no
/// block table, no backend state).
///
/// This is the unit of cross-shard offline work stealing
/// ([`crate::shard::steal`]): the donor converts a queued request into a
/// `PortableRequest` with [`PortableRequest::detach`] (after detaching
/// its host-checkpoint accounting via
/// [`KvManager::export_host`](crate::kvcache::KvManager::export_host)),
/// and the target rebuilds it with [`PortableRequest::into_request`] and
/// a fresh arena insertion. `submitted_id` and `sampler_state` travel
/// with it, so result correlation and token streams are unchanged by the
/// move; the donor's old arena id dies with the donor-side removal (its
/// generation is bumped and its shard bits never match the target).
#[derive(Debug, Clone)]
pub struct PortableRequest {
    pub submitted_id: u64,
    pub class: Class,
    pub prompt: Vec<TokenId>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
    pub arrival: TimeUs,
    /// Generated output tokens so far (real path; empty in sim).
    pub output: Vec<TokenId>,
    pub generated: usize,
    /// Committed tokens covered by the migrated host-checkpoint prefix
    /// (0 = cold steal: the request restarts from prefill on the target).
    pub ckpt_tokens: usize,
    pub preemptions: u32,
    pub recomputed_tokens: usize,
    pub first_token_at: Option<TimeUs>,
    pub last_token_at: Option<TimeUs>,
    /// Per-request sampler key seed (see [`Request::sampler_state`]).
    pub sampler_state: u64,
    /// Batch-job identity (see the corresponding [`Request`] fields);
    /// travels with the request across shards and process restarts.
    pub job: u64,
    pub tenant: u32,
    pub urgency: u32,
    pub fair_weight: u32,
    pub deadline: TimeUs,
}

impl PortableRequest {
    /// Detach `r` from its shard. `ckpt_tokens` is what the donor's
    /// `KvManager::export_host` reported: the committed prefix whose host
    /// checkpoints travel with the request (0 when it held no KV).
    pub fn detach(r: Request, ckpt_tokens: usize) -> Self {
        debug_assert_eq!(
            ckpt_tokens, r.ctx_len,
            "exported checkpoint must cover exactly the committed tokens"
        );
        Self {
            submitted_id: r.submitted_id,
            class: r.class,
            prompt: r.prompt,
            prompt_len: r.prompt_len,
            max_new_tokens: r.max_new_tokens,
            arrival: r.arrival,
            output: r.output,
            generated: r.generated,
            ckpt_tokens,
            preemptions: r.preemptions,
            recomputed_tokens: r.recomputed_tokens,
            first_token_at: r.first_token_at,
            last_token_at: r.last_token_at,
            sampler_state: r.sampler_state,
            job: r.job,
            tenant: r.tenant,
            urgency: r.urgency,
            fair_weight: r.fair_weight,
            deadline: r.deadline,
        }
    }

    /// Snapshot a live request as a *cold* portable (no KV travels): the
    /// durable-store checkpoint form ([`crate::batch::JobStore`]). Host
    /// checkpoints are process-lifetime state, so a crash/restart resume
    /// always recomputes prefill — the token stream is still exact
    /// because sampling is keyed by `(sampler_state, position)`.
    pub fn snapshot_cold(r: &Request) -> Self {
        // the committed context is forfeited by the snapshot without a
        // recompute charge — the resume run accounts its own recompute
        Self::detach(
            Request {
                ctx_len: 0,
                ckpt_len: 0,
                ..r.clone()
            },
            0,
        )
    }

    /// Rebuild an insertable [`Request`] on the target shard. The id is
    /// provisional (0) until the target arena re-keys it on insertion;
    /// `submitted_id` is preserved so callers still correlate results.
    /// With a migrated checkpoint the request arrives `Host`-resident
    /// (resume = prefetch of the imported host blocks); cold steals
    /// arrive like fresh admissions.
    pub fn into_request(self) -> Request {
        let ckpt = self.ckpt_tokens;
        let mut r = Request::new(
            0,
            self.class,
            self.prompt,
            self.prompt_len,
            self.max_new_tokens,
            self.arrival,
        );
        r.submitted_id = self.submitted_id;
        r.sampler_state = self.sampler_state;
        r.job = self.job;
        r.tenant = self.tenant;
        r.urgency = self.urgency;
        r.fair_weight = self.fair_weight;
        r.deadline = self.deadline;
        r.output = self.output;
        r.generated = self.generated;
        r.ctx_len = ckpt;
        r.ckpt_len = ckpt;
        r.preemptions = self.preemptions;
        r.recomputed_tokens = self.recomputed_tokens;
        r.first_token_at = self.first_token_at;
        r.last_token_at = self.last_token_at;
        r.state = State::Waiting;
        r.residence = if ckpt > 0 {
            KvResidence::Host
        } else {
            KvResidence::Gpu
        };
        r
    }

    /// Serialize for the durable job store (one JSONL line). Exhaustive:
    /// every field round-trips, so a resumed request is indistinguishable
    /// from the in-memory original (see `from_json`).
    pub fn to_json(&self) -> Json {
        // sid and sampler_state are full 64-bit values (tickets set bit
        // 63; sampler states are mix64 outputs): JSON numbers are f64
        // and would silently round above 2^53, so both go as decimal
        // strings to keep resume byte-exact.
        obj(vec![
            ("sid", Json::Str(self.submitted_id.to_string())),
            (
                "class",
                Json::Str(
                    match self.class {
                        Class::Online => "online",
                        Class::Offline => "offline",
                    }
                    .to_string(),
                ),
            ),
            ("prompt", tok_arr(&self.prompt)),
            ("prompt_len", num(self.prompt_len as f64)),
            ("max_new", num(self.max_new_tokens as f64)),
            ("arrival", num(self.arrival as f64)),
            ("output", tok_arr(&self.output)),
            ("generated", num(self.generated as f64)),
            ("ckpt_tokens", num(self.ckpt_tokens as f64)),
            ("preemptions", num(self.preemptions as f64)),
            ("recomputed", num(self.recomputed_tokens as f64)),
            ("first_token_at", opt_num(self.first_token_at)),
            ("last_token_at", opt_num(self.last_token_at)),
            ("sampler_state", Json::Str(self.sampler_state.to_string())),
            ("job", num(self.job as f64)),
            ("tenant", num(self.tenant as f64)),
            ("urgency", num(self.urgency as f64)),
            ("fair_weight", num(self.fair_weight as f64)),
            ("deadline", num(self.deadline as f64)),
        ])
    }

    /// Parse a store line back into a portable request. Checkpoints
    /// written by [`snapshot_cold`](Self::snapshot_cold) always carry
    /// `ckpt_tokens == 0`; a nonzero value from a hand-edited store is
    /// clamped to 0 (host KV never survives the process).
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        const WHAT: &str = "portable request";
        let f = |k: &str| json_f64(j, WHAT, k);
        let class = match j.get("class").and_then(Json::as_str) {
            Some("online") => Class::Online,
            Some("offline") => Class::Offline,
            other => anyhow::bail!("portable request: bad class {other:?}"),
        };
        Ok(Self {
            submitted_id: json_u64_str(j, WHAT, "sid")?,
            class,
            prompt: tok_vec(j.get("prompt"), WHAT)?,
            prompt_len: f("prompt_len")? as usize,
            max_new_tokens: f("max_new")? as usize,
            arrival: f("arrival")? as TimeUs,
            output: tok_vec(j.get("output"), WHAT)?,
            generated: f("generated")? as usize,
            ckpt_tokens: 0,
            preemptions: f("preemptions")? as u32,
            recomputed_tokens: f("recomputed")? as usize,
            first_token_at: j.get("first_token_at").and_then(Json::as_f64).map(|v| v as TimeUs),
            last_token_at: j.get("last_token_at").and_then(Json::as_f64).map(|v| v as TimeUs),
            sampler_state: json_u64_str(j, WHAT, "sampler_state")?,
            job: f("job")? as u64,
            tenant: f("tenant")? as u32,
            urgency: f("urgency")? as u32,
            fair_weight: f("fair_weight")? as u32,
            deadline: f("deadline")? as TimeUs,
        })
    }
}

/// Shared serde helpers for the request/store JSONL surface (`what`
/// names the record kind in error messages) — the durable job store
/// ([`crate::batch::store`]) parses with these same functions, so the
/// two surfaces cannot drift.
pub(crate) fn tok_arr(toks: &[TokenId]) -> Json {
    arr(toks.iter().map(|&t| num(t as f64)))
}

pub(crate) fn tok_vec(j: Option<&Json>, what: &str) -> anyhow::Result<Vec<TokenId>> {
    match j {
        Some(Json::Arr(a)) => a
            .iter()
            .map(|v| {
                v.as_f64()
                    .map(|n| n as TokenId)
                    .ok_or_else(|| anyhow::anyhow!("{what}: non-numeric token"))
            })
            .collect(),
        _ => anyhow::bail!("{what}: missing token array"),
    }
}

/// Required numeric field.
pub(crate) fn json_f64(j: &Json, what: &str, k: &str) -> anyhow::Result<f64> {
    j.get(k)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("{what}: missing field `{k}`"))
}

/// Required full-width u64 field, stored as a decimal string (JSON
/// numbers are f64 and would round above 2^53).
pub(crate) fn json_u64_str(j: &Json, what: &str, k: &str) -> anyhow::Result<u64> {
    j.get(k)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("{what}: missing field `{k}`"))?
        .parse()
        .map_err(|e| anyhow::anyhow!("{what}: bad u64 `{k}`: {e}"))
}

fn opt_num(v: Option<TimeUs>) -> Json {
    match v {
        Some(t) => num(t as f64),
        None => Json::Null,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request::new(1, Class::Online, vec![], 100, 20, 0)
    }

    #[test]
    fn id_layout_round_trips() {
        let id = rid_pack_sharded(5, 1234, 77);
        assert_eq!(rid_shard(id), 5);
        assert_eq!(rid_slot(id), 1234);
        assert_eq!(rid_gen(id), 77);
        // shard 0 packing is the legacy (slot, generation) layout
        assert_eq!(rid_pack(1234, 77), rid_pack_sharded(0, 1234, 77));
        // same (slot, generation) in different shards -> distinct ids
        assert_ne!(rid_pack_sharded(1, 1234, 77), rid_pack_sharded(2, 1234, 77));
        // extremes stay in range
        let hi = rid_pack_sharded(MAX_SHARDS - 1, SLOTS_PER_SHARD - 1, u32::MAX);
        assert_eq!(rid_shard(hi), MAX_SHARDS - 1);
        assert_eq!(rid_slot(hi), SLOTS_PER_SHARD - 1);
        assert_eq!(rid_gen(hi), u32::MAX);
    }

    #[test]
    fn phase_transitions() {
        let mut r = req();
        assert_eq!(r.phase(), Phase::Prefill);
        assert_eq!(r.remaining_feed(), 100);
        r.ctx_len = 64;
        assert_eq!(r.phase(), Phase::Prefill);
        assert_eq!(r.remaining_feed(), 36);
        // prefill complete + first token sampled
        r.ctx_len = 100;
        r.generated = 1;
        assert_eq!(r.phase(), Phase::Decode);
        assert_eq!(r.remaining_feed(), 1);
        // each decode feeds one token
        r.ctx_len = 101;
        r.generated = 2;
        assert_eq!(r.phase(), Phase::Decode);
    }

    #[test]
    fn discard_recompute_covers_outputs() {
        let mut r = req();
        r.ctx_len = 105; // prefilled 100 + 5 decode steps committed
        r.generated = 6;
        // discard-preemption: KV gone, 6 outputs known
        r.ctx_len = 0;
        assert_eq!(r.feed_target(), 106);
        assert_eq!(r.remaining_feed(), 106);
        assert_eq!(r.phase(), Phase::Prefill);
    }

    #[test]
    fn feed_tokens_spans_prompt_and_output() {
        let mut r = Request::new(1, Class::Online, vec![10, 11, 12], 3, 4, 0);
        r.output = vec![20, 21];
        r.generated = 2;
        r.ctx_len = 2;
        assert_eq!(r.feed_tokens(3), vec![12, 20, 21]);
    }

    #[test]
    fn done_when_outputs_generated() {
        let mut r = req();
        assert!(!r.is_done());
        r.generated = 20;
        assert!(r.is_done());
        assert_eq!(r.total_len(), 120);
    }

    #[test]
    fn ttft_measured_from_arrival() {
        let mut r = Request::new(1, Class::Online, vec![], 10, 5, 1000);
        assert_eq!(r.ttft(), None);
        r.first_token_at = Some(3500);
        assert_eq!(r.ttft(), Some(2500));
    }

    #[test]
    fn feed_tokens_into_matches_allocating_path() {
        let mut r = Request::new(1, Class::Online, vec![10, 11, 12], 3, 4, 0);
        r.output = vec![20, 21];
        r.generated = 2;
        r.ctx_len = 1;
        let mut buf = vec![99]; // appended, not cleared
        r.feed_tokens_into(4, &mut buf);
        assert_eq!(buf, vec![99, 11, 12, 20, 21]);
        assert_eq!(r.feed_tokens(4), vec![11, 12, 20, 21]);
    }

    #[test]
    fn portable_round_trip_preserves_identity_and_tokens() {
        let mut r = Request::new(7, Class::Offline, vec![1, 2, 3], 3, 8, 500);
        r.submitted_id = 7;
        r.output = vec![40, 41, 42];
        r.generated = 3;
        r.ctx_len = 5;
        r.preemptions = 2;
        r.recomputed_tokens = 9;
        let state = r.sampler_state;
        // simulate an arena re-keying before migration
        r.id = rid_pack_sharded(3, 12, 4);

        let p = PortableRequest::detach(r, 5);
        assert_eq!(p.submitted_id, 7);
        assert_eq!(p.sampler_state, state);
        let back = p.into_request();
        assert_eq!(back.id, 0, "id is provisional until target insertion");
        assert_eq!(back.submitted_id, 7);
        assert_eq!(back.sampler_state, state);
        assert_eq!(back.output, vec![40, 41, 42]);
        assert_eq!(back.generated, 3);
        assert_eq!(back.ctx_len, 5);
        assert_eq!(back.ckpt_len, 5);
        assert_eq!(back.residence, KvResidence::Host);
        assert_eq!(back.state, State::Waiting);
        assert_eq!(back.preemptions, 2);
        assert_eq!(back.recomputed_tokens, 9);
        // resumes exactly where the donor stopped: one decode step next
        assert_eq!(back.remaining_feed(), 1);
        assert_eq!(back.phase(), Phase::Decode);
    }

    #[test]
    fn portable_cold_steal_restarts_from_prefill() {
        let mut r = Request::new(9, Class::Offline, vec![], 100, 10, 0);
        r.generated = 3; // discarded-preempted progress, ctx already 0
        let p = PortableRequest::detach(r, 0);
        let back = p.into_request();
        assert_eq!(back.residence, KvResidence::Gpu);
        assert_eq!(back.ctx_len, 0);
        assert_eq!(back.remaining_feed(), 103);
        assert_eq!(back.phase(), Phase::Prefill);
    }

    #[test]
    fn portable_json_round_trip_is_lossless() {
        let mut r = Request::new(0x8000_0000_0000_002A, Class::Offline, vec![5, 6, 7], 3, 9, 123);
        r.output = vec![1, 2];
        r.generated = 2;
        r.preemptions = 1;
        r.first_token_at = Some(777);
        r.job = 3;
        r.tenant = 4;
        r.urgency = 800;
        r.fair_weight = 2;
        r.deadline = 999_999;
        let p = PortableRequest::snapshot_cold(&r);
        let line = p.to_json().to_string();
        let back = PortableRequest::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.submitted_id, p.submitted_id, "ticket-bit sid survives");
        assert_eq!(back.sampler_state, p.sampler_state, "full 64-bit state survives");
        assert_eq!(back.prompt, p.prompt);
        assert_eq!(back.output, p.output);
        assert_eq!(back.generated, 2);
        assert_eq!(back.ckpt_tokens, 0, "store checkpoints are always cold");
        assert_eq!(back.first_token_at, Some(777));
        assert_eq!(back.last_token_at, None);
        assert_eq!(
            (back.job, back.tenant, back.urgency, back.fair_weight, back.deadline),
            (3, 4, 800, 2, 999_999)
        );
        // a resumed request regenerates the same keyed token stream
        let resumed = back.into_request();
        assert_eq!(resumed.sampler_state, r.sampler_state);
        assert_eq!(resumed.remaining_feed(), 3 + 2, "cold resume recomputes prefill");
    }

    #[test]
    fn snapshot_cold_drops_kv_but_keeps_progress() {
        let mut r = Request::new(11, Class::Offline, vec![], 64, 8, 0);
        r.ctx_len = 40;
        r.ckpt_len = 32;
        r.generated = 3;
        let p = PortableRequest::snapshot_cold(&r);
        assert_eq!(p.ckpt_tokens, 0);
        assert_eq!(p.generated, 3);
        assert_eq!(p.recomputed_tokens, 0, "snapshot itself charges no recompute");
        let back = p.into_request();
        assert_eq!(back.ctx_len, 0);
        assert_eq!(back.generated, 3);
        assert_eq!(back.residence, KvResidence::Gpu);
    }

    #[test]
    fn sampler_state_is_shard_invariant() {
        // same submission id => same sampler state, regardless of which
        // shard's arena later re-keys the request
        let a = Request::new(42, Class::Online, vec![], 8, 2, 0);
        let b = Request::new(42, Class::Online, vec![], 8, 2, 0);
        let c = Request::new(43, Class::Online, vec![], 8, 2, 0);
        assert_eq!(a.sampler_state, b.sampler_state);
        assert_ne!(a.sampler_state, c.sampler_state);
    }
}
