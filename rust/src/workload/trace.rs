//! Trace synthesis: a BurstGPT-like rate curve (paper Fig. 1: diurnal
//! pattern, avg ~1050 tok/s, peak ~3743 tok/s, 3x minute-scale bursts)
//! and the ON/OFF square-wave load of §6.3.1.
//!
//! The paper samples and time-rescales the real campus trace (§6.1); we
//! synthesize a rate curve with the same published statistics and drive a
//! non-homogeneous gamma/Poisson arrival process from it.

use crate::request::{Class, Request, TokenId};
use crate::util::rng::Rng;
use crate::{TimeUs, US_PER_SEC};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub t: TimeUs,
}

/// Request rate (req/s) at time `t_s` for a BurstGPT-like curve scaled to
/// `base_rate` (the paper's Fig.-1b 15-minute slice rescaled to the
/// experiment duration).
///
/// Components: a slow diurnal-ish swell across the window, a mid-scale
/// wave, and a deterministic 3x burst around 2/3 of the window (Fig. 1b
/// "the request rate increases by 3x in the tenth minute").
pub fn burstgpt_like_rate(t_s: f64, duration_s: f64, base_rate: f64) -> f64 {
    let x = (t_s / duration_s).clamp(0.0, 1.0);
    // slow swell: low start, high middle-late
    let swell = 0.55 + 0.45 * (std::f64::consts::PI * (x * 0.9 + 0.05)).sin();
    // mid-scale fluctuation (minutes-scale in the 15-min trace)
    let wave = 1.0 + 0.25 * (2.0 * std::f64::consts::PI * 6.0 * x).sin();
    // burst at ~2/3 of the window: ramp to 3x over ~5% of the window
    let burst = {
        let c = 0.66;
        let w = 0.05;
        let d = ((x - c) / w).abs();
        if d < 1.0 {
            1.0 + 2.0 * (1.0 - d) // peaks at 3x
        } else {
            1.0
        }
    };
    (base_rate * swell * wave * burst).max(base_rate * 0.05)
}

/// Arrival timestamps over [0, duration_s) following the BurstGPT-like
/// curve via thinning of a gamma process (burstiness `cv` within the
/// rate envelope).
pub fn burstgpt_like_arrivals(
    seed: u64,
    duration_s: f64,
    base_rate: f64,
    cv: f64,
) -> Vec<TimeUs> {
    let peak = 3.2 * base_rate; // envelope upper bound
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        t += rng.gamma_interarrival(peak, cv);
        if t >= duration_s {
            break;
        }
        let accept = burstgpt_like_rate(t, duration_s, base_rate) / peak;
        if rng.f64() < accept {
            out.push((t * US_PER_SEC as f64) as TimeUs);
        }
    }
    out
}

/// ON/OFF phased arrivals (§6.3.1): `on_rate` req/s during ON windows,
/// zero during OFF. `phase_s` is the length of each phase; the trace
/// starts in ON.
pub fn onoff_trace(
    seed: u64,
    duration_s: f64,
    phase_s: f64,
    on_rate: f64,
    cv: f64,
) -> Vec<TimeUs> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        t += rng.gamma_interarrival(on_rate, cv);
        if t >= duration_s {
            break;
        }
        let phase = (t / phase_s) as u64;
        if phase % 2 == 0 {
            out.push((t * US_PER_SEC as f64) as TimeUs);
        }
    }
    out
}

/// Bursty square-wave arrivals with a nonzero floor: `on_rate` req/s
/// during ON phases, `off_rate` during OFF (alternating `phase_s`-long
/// phases, starting ON), with seeded gamma jitter (`cv`) inside each
/// phase. Unlike [`onoff_trace`], the OFF floor keeps online latency
/// samples flowing through the troughs — the regime the harvest
/// controller's hysteresis is tuned against.
pub fn square_wave_trace(
    seed: u64,
    duration_s: f64,
    phase_s: f64,
    on_rate: f64,
    off_rate: f64,
    cv: f64,
) -> Vec<TimeUs> {
    let peak = on_rate.max(off_rate).max(1e-9);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        t += rng.gamma_interarrival(peak, cv);
        if t >= duration_s {
            break;
        }
        let rate = if ((t / phase_s) as u64) % 2 == 0 {
            on_rate
        } else {
            off_rate
        };
        if rng.f64() < rate / peak {
            out.push((t * US_PER_SEC as f64) as TimeUs);
        }
    }
    out
}

/// Flash-crowd arrivals: a steady `base_rate` with one `mult`x burst
/// over `[burst_start_s, burst_start_s + burst_s)`, gamma-jittered
/// (`cv`) and fully determined by `seed`. Models the paper's Fig.-1b
/// "rate increases by 3x" spike as an isolated event a controller must
/// react to within the burst, not after it.
#[allow(clippy::too_many_arguments)]
pub fn flash_crowd_trace(
    seed: u64,
    duration_s: f64,
    base_rate: f64,
    burst_start_s: f64,
    burst_s: f64,
    mult: f64,
    cv: f64,
) -> Vec<TimeUs> {
    let peak = base_rate * mult.max(1.0);
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    let mut t = 0.0f64;
    loop {
        t += rng.gamma_interarrival(peak, cv);
        if t >= duration_s {
            break;
        }
        let in_burst = t >= burst_start_s && t < burst_start_s + burst_s;
        let rate = if in_burst { base_rate * mult } else { base_rate };
        if rng.f64() < rate / peak {
            out.push((t * US_PER_SEC as f64) as TimeUs);
        }
    }
    out
}

/// Knobs for [`chat_trace`].
#[derive(Debug, Clone)]
pub struct ChatTraceConfig {
    pub seed: u64,
    /// Concurrent chat sessions, started staggered across the window.
    pub sessions: usize,
    /// Turns per session; each turn resubmits the whole history.
    pub turns: usize,
    /// Shared system-prompt length (tokens) — identical across all
    /// sessions, so it is the cross-*session* shareable prefix.
    pub system_tokens: usize,
    /// Fresh user tokens appended per turn.
    pub user_tokens: usize,
    /// Assistant-reply tokens appended to the history after each turn
    /// (the sim backend synthesizes outputs, so the history carries a
    /// seeded stand-in of the same length).
    pub reply_tokens: usize,
    /// Decode budget per turn.
    pub max_new_tokens: usize,
    /// Submission window (s): sessions start uniformly over the first
    /// half; think-time between turns fills the rest.
    pub span_s: f64,
}

impl Default for ChatTraceConfig {
    fn default() -> Self {
        Self {
            seed: 0xC4A7,
            sessions: 16,
            turns: 6,
            system_tokens: 96,
            user_tokens: 24,
            reply_tokens: 32,
            max_new_tokens: 32,
            span_s: 60.0,
        }
    }
}

/// Multi-turn chat trace with *real* prompt token vectors — the
/// workload cross-request prefix KV sharing is built for
/// ([`crate::kvcache::prefix`]).
///
/// Every session opens with the same system prompt (cross-session
/// sharing) and each turn resubmits the full history — system prompt,
/// prior user turns, and seeded stand-ins for the assistant replies —
/// plus one fresh user utterance (cross-turn sharing: turn `t+1`'s
/// prompt extends turn `t`'s). Arrivals interleave sessions: staggered
/// starts plus seeded think-time between turns, globally sorted, so
/// consecutive admissions usually belong to *different* sessions and a
/// cache keyed on exact last-request state (rather than a prefix trie)
/// would miss.
///
/// Requests are `Class::Online` with unique ids (stable across runs of
/// the same config), so token streams replay byte-identically and runs
/// with sharing on/off are directly comparable.
pub fn chat_trace(cfg: &ChatTraceConfig) -> Vec<Request> {
    let mut rng = Rng::new(cfg.seed);
    // byte-level vocab: keep token values in 0..256 like the datasets
    let tok = |rng: &mut Rng| rng.range(0, 256) as TokenId;
    let system: Vec<TokenId> = (0..cfg.system_tokens).map(|_| tok(&mut rng)).collect();
    let mut out = Vec::with_capacity(cfg.sessions * cfg.turns);
    let mut id: u64 = 1;
    for _ in 0..cfg.sessions {
        let mut history = system.clone();
        // stagger session starts over the first half of the window
        let mut t_s = rng.f64() * cfg.span_s * 0.5;
        for _ in 0..cfg.turns {
            for _ in 0..cfg.user_tokens {
                history.push(tok(&mut rng));
            }
            let prompt = history.clone();
            let plen = prompt.len();
            let arrival = (t_s * US_PER_SEC as f64) as TimeUs;
            out.push(Request::new(
                id,
                Class::Online,
                prompt,
                plen,
                cfg.max_new_tokens,
                arrival,
            ));
            id += 1;
            // stand-in assistant reply joins the history for next turn
            for _ in 0..cfg.reply_tokens {
                history.push(tok(&mut rng));
            }
            // think-time: mean half the remaining per-turn budget
            let mean_gap = (cfg.span_s * 0.5 / cfg.turns.max(1) as f64).max(0.1);
            t_s += rng.exp(1.0 / mean_gap);
        }
    }
    out.sort_by_key(|r| r.arrival);
    out
}

/// Summarize a trace into per-window token rates (for Fig.-1 style
/// reporting): returns (window_start_s, requests, est_tokens_per_s).
pub fn rate_series(
    arrivals: &[TimeUs],
    tokens_per_req: usize,
    window_s: f64,
    duration_s: f64,
) -> Vec<(f64, usize, f64)> {
    let mut out = Vec::new();
    let mut start = 0.0f64;
    while start < duration_s {
        let end = start + window_s;
        let n = arrivals
            .iter()
            .filter(|&&t| {
                let s = t as f64 / US_PER_SEC as f64;
                s >= start && s < end
            })
            .count();
        out.push((start, n, n as f64 * tokens_per_req as f64 / window_s));
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burst_peaks_near_two_thirds() {
        let d = 900.0;
        let base = 1.0;
        let at_burst = burstgpt_like_rate(0.66 * d, d, base);
        let before = burstgpt_like_rate(0.4 * d, d, base);
        assert!(
            at_burst > 2.0 * before,
            "burst {at_burst} vs before {before}"
        );
    }

    #[test]
    fn arrivals_follow_envelope() {
        let a = burstgpt_like_arrivals(11, 900.0, 2.0, 1.0);
        // mean acceptance ~ avg(rate)/peak; just sanity-check volume
        let rate = a.len() as f64 / 900.0;
        assert!(rate > 0.8 && rate < 4.0, "rate={rate}");
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn burst_visible_in_series() {
        let a = burstgpt_like_arrivals(12, 900.0, 4.0, 1.0);
        let series = rate_series(&a, 1152, 30.0, 900.0);
        let burst_window = series
            .iter()
            .filter(|(s, _, _)| (*s >= 540.0) && (*s < 630.0))
            .map(|(_, n, _)| *n)
            .max()
            .unwrap();
        let early_max = series
            .iter()
            .filter(|(s, _, _)| *s < 300.0)
            .map(|(_, n, _)| *n)
            .max()
            .unwrap();
        assert!(
            burst_window as f64 > 1.5 * early_max as f64,
            "burst={burst_window} early={early_max}"
        );
    }

    #[test]
    fn square_wave_holds_both_rates_and_is_seeded() {
        let a = square_wave_trace(21, 600.0, 150.0, 8.0, 1.0, 1.0);
        let on: usize = a
            .iter()
            .filter(|&&t| ((t / US_PER_SEC) / 150) % 2 == 0)
            .count();
        let off = a.len() - on;
        // two ON + two OFF phases of 150 s each
        let on_rate = on as f64 / 300.0;
        let off_rate = off as f64 / 300.0;
        assert!((on_rate - 8.0).abs() < 1.2, "on_rate={on_rate}");
        assert!((off_rate - 1.0).abs() < 0.5, "off_rate={off_rate}");
        assert!(off > 0, "OFF floor must keep samples flowing");
        // deterministic in the seed
        assert_eq!(a, square_wave_trace(21, 600.0, 150.0, 8.0, 1.0, 1.0));
        assert_ne!(a, square_wave_trace(22, 600.0, 150.0, 8.0, 1.0, 1.0));
    }

    #[test]
    fn flash_crowd_concentrates_in_the_burst() {
        let a = flash_crowd_trace(31, 600.0, 2.0, 300.0, 60.0, 4.0, 1.0);
        let in_burst = a
            .iter()
            .filter(|&&t| (300..360).contains(&(t / US_PER_SEC)))
            .count();
        let burst_rate = in_burst as f64 / 60.0;
        let base_rate = (a.len() - in_burst) as f64 / 540.0;
        assert!(
            burst_rate > 2.5 * base_rate,
            "burst_rate={burst_rate} base_rate={base_rate}"
        );
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(a, flash_crowd_trace(31, 600.0, 2.0, 300.0, 60.0, 4.0, 1.0));
    }

    #[test]
    fn chat_trace_is_deterministic_and_sorted() {
        let cfg = ChatTraceConfig::default();
        let a = chat_trace(&cfg);
        assert_eq!(a.len(), cfg.sessions * cfg.turns);
        let b = chat_trace(&cfg);
        assert!(
            a.iter().zip(&b).all(|(x, y)| {
                (x.id, x.arrival, &x.prompt) == (y.id, y.arrival, &y.prompt)
            }),
            "same seed must replay"
        );
        let other = chat_trace(&ChatTraceConfig { seed: 7, ..cfg.clone() });
        assert!(
            a.iter().zip(&other).any(|(x, y)| x.prompt != y.prompt),
            "different seed must differ"
        );
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        let mut ids: Vec<_> = a.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), a.len(), "ids must be unique");
        for r in &a {
            assert_eq!(r.prompt.len(), r.prompt_len);
        }
    }

    #[test]
    fn chat_trace_shares_the_system_prompt_across_sessions() {
        let cfg = ChatTraceConfig::default();
        let a = chat_trace(&cfg);
        let system = &a[0].prompt[..cfg.system_tokens];
        for r in &a {
            assert_eq!(
                &r.prompt[..cfg.system_tokens],
                system,
                "every prompt opens with the shared system prompt"
            );
        }
    }

    #[test]
    fn chat_trace_turns_extend_their_session_history() {
        // one session: sorted order == turn order, so each prompt must
        // be a strict extension of the previous one
        let cfg = ChatTraceConfig {
            sessions: 1,
            turns: 5,
            ..ChatTraceConfig::default()
        };
        let a = chat_trace(&cfg);
        for w in a.windows(2) {
            let (prev, next) = (&w[0].prompt, &w[1].prompt);
            assert!(next.len() > prev.len(), "histories must grow");
            assert_eq!(&next[..prev.len()], &prev[..], "turn t+1 extends turn t");
        }
    }

    #[test]
    fn onoff_phases_alternate() {
        let a = onoff_trace(13, 720.0, 180.0, 8.0, 1.0);
        let in_on = a
            .iter()
            .filter(|&&t| {
                let s = t / US_PER_SEC;
                !(180..360).contains(&s) && !(540..720).contains(&s)
            })
            .count();
        assert_eq!(in_on, a.len(), "no arrivals during OFF phases");
        // ON phases carry ~8 req/s
        let rate = a.len() as f64 / 360.0;
        assert!((rate - 8.0).abs() < 1.0, "rate={rate}");
    }
}
