"""Model + export configuration for the ConServe artifact pipeline.

The "real path" model is a Llama-architecture transformer small enough to
serve end-to-end on the CPU PJRT client: byte-level vocab, 4 layers, GQA.
The architecture (RMSNorm -> GQA attention with RoPE -> SwiGLU) matches
Llama-2 exactly so the layered export is representative of the paper's
Llama-2-7B testbed; only the dimensions are scaled down.

Buckets: XLA AOT requires static shapes, so every entry point is exported
at a grid of (batch, chunk) buckets. The Rust engine pads each scheduled
sub-batch up to the nearest bucket. T=1 is the decode bucket; larger T
buckets serve chunked prefill.
"""

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 256          # byte-level tokenizer
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2            # GQA, like Llama-2-70B / Llama-3
    head_dim: int = 32
    d_ffn: int = 256
    max_seq: int = 256             # KV-cache slots per sequence
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim


@dataclass(frozen=True)
class ExportConfig:
    batch_buckets: Tuple[int, ...] = (1, 4, 8)
    chunk_buckets: Tuple[int, ...] = (1, 16, 64)
    seed: int = 20240607


MODEL = ModelConfig()
EXPORT = ExportConfig()


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list for every model tensor.

    This order is the layout of weights.bin and is mirrored in
    manifest.json; the Rust runtime indexes tensors by name.
    """
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embedding", (cfg.vocab_size, cfg.d_model)),
    ]
    for l in range(cfg.n_layers):
        p = f"layers.{l}."
        specs += [
            (p + "attn_norm", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.q_dim)),
            (p + "wk", (cfg.d_model, cfg.kv_dim)),
            (p + "wv", (cfg.d_model, cfg.kv_dim)),
            (p + "wo", (cfg.q_dim, cfg.d_model)),
            (p + "mlp_norm", (cfg.d_model,)),
            (p + "w_gate", (cfg.d_model, cfg.d_ffn)),
            (p + "w_up", (cfg.d_model, cfg.d_ffn)),
            (p + "w_down", (cfg.d_ffn, cfg.d_model)),
        ]
    specs += [
        ("final_norm", (cfg.d_model,)),
        ("lm_head", (cfg.d_model, cfg.vocab_size)),
    ]
    return specs


LAYER_WEIGHT_NAMES = (
    "attn_norm",
    "wq",
    "wk",
    "wv",
    "wo",
    "mlp_norm",
    "w_gate",
    "w_up",
    "w_down",
)
