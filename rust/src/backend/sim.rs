//! Discrete-event execution backend: models the A100/Llama-2-7B testbed
//! by advancing a shared virtual clock according to the cost model.
//!
//! Execution is split into `n_layers / safepoint_layers` layer groups;
//! preemptible iterations pay the safepoint barrier cost between groups
//! and invoke the engine's safepoint callback — the exact control flow
//! of the paper's instrumented worker (§4.3), with modelled time instead
//! of CUDA kernels.

use super::{
    CostModel, ExecBackend, ExecOutcome, IterationPlan, PlanSummary, SafepointAction,
};
use crate::clock::Clock;
use crate::request::RequestId;

pub struct SimBackend {
    pub cost: CostModel,
    clock: Clock,
    safepoint_layers: usize,
    synth_tokens: bool,
}

impl SimBackend {
    /// Under a virtual clock the backend *advances* time by the modelled
    /// cost (deterministic discrete-event mode, used by every benchmark).
    /// Under a real clock it *sleeps* the modelled cost instead, pacing
    /// wall time like the modelled GPU — the live front door
    /// ([`crate::server::http`]) runs this mode so loopback smoke tests
    /// exercise real threads, sockets and timing without hardware.
    pub fn new(cost: CostModel, clock: Clock, safepoint_layers: usize) -> Self {
        let safepoint_layers = safepoint_layers.clamp(1, cost.n_layers);
        Self {
            cost,
            clock,
            safepoint_layers,
            synth_tokens: false,
        }
    }

    /// Pass modelled time: advance a virtual clock, sleep a real one.
    fn pace(&self, dt: u64) {
        if self.clock.is_virtual() {
            self.clock.advance(dt);
        } else {
            std::thread::sleep(std::time::Duration::from_micros(dt));
        }
    }

    pub fn clock(&self) -> Clock {
        self.clock.clone()
    }

    /// Synthesize deterministic output tokens from each item's
    /// `sample_key` (off by default — the steady-state sim loop then
    /// allocates nothing per iteration). The key mixes the request's
    /// sampler state with its output position, so a synthesized token
    /// stream is invariant under chunking, batching, migration *and*
    /// process restart — which is what lets the durable-store
    /// kill-and-resume tests assert byte-identical outputs on the
    /// simulator.
    pub fn set_synth_tokens(&mut self, on: bool) {
        self.synth_tokens = on;
    }

    fn synth(&self, plan: &IterationPlan) -> Vec<Option<crate::request::TokenId>> {
        if !self.synth_tokens {
            return Vec::new();
        }
        plan.items
            .iter()
            .map(|it| Some((it.sample_key & 0xFF) as crate::request::TokenId))
            .collect()
    }
}

impl ExecBackend for SimBackend {
    fn execute(
        &mut self,
        plan: &IterationPlan,
        safepoint: &mut dyn FnMut(crate::TimeUs) -> SafepointAction,
    ) -> anyhow::Result<ExecOutcome> {
        let s = plan.summary();
        let total =
            self.cost
                .iter_us(s.prefill_tokens, s.decode_seqs, s.ctx_tokens, s.n_seqs);
        let groups = self.n_layer_groups();
        let per_group = total / groups as u64;
        let start = self.clock.now();
        let mut checks = 0;

        for g in 0..groups {
            // last group gets the rounding remainder
            let dt = if g == groups - 1 {
                total - per_group * (groups as u64 - 1)
            } else {
                per_group
            };
            self.pace(dt);
            if plan.preemptible && g + 1 < groups {
                // barrier + flag check between layer groups (§4.3)
                self.pace(self.cost.safepoint_us);
                checks += 1;
                if safepoint(self.clock.now()) == SafepointAction::Abort {
                    return Ok(ExecOutcome {
                        completed: false,
                        // nothing commits from an aborted batch
                        new_tokens: Vec::new(),
                        elapsed_us: self.clock.now() - start,
                        safepoint_checks: checks,
                    });
                }
            }
        }
        Ok(ExecOutcome {
            completed: true,
            // default: no tokens, no allocation (see set_synth_tokens)
            new_tokens: self.synth(plan),
            elapsed_us: self.clock.now() - start,
            safepoint_checks: checks,
        })
    }

    fn probe_us(&mut self, s: &PlanSummary) -> u64 {
        self.cost
            .iter_us(s.prefill_tokens, s.decode_seqs, s.ctx_tokens, s.n_seqs)
    }

    fn drop_request(&mut self, _req: RequestId) {}

    fn copy_block_d2h(&mut self, _req: RequestId, _idx: usize, _bt: usize) {}

    fn copy_block_h2d(&mut self, _req: RequestId, _idx: usize, _bt: usize) {}

    fn block_bytes(&self) -> u64 {
        self.cost.block_bytes()
    }

    fn link_bandwidth(&self) -> u64 {
        self.cost.pcie_bytes_per_sec
    }

    fn safepoint_cost_us(&self) -> u64 {
        self.cost.safepoint_us
    }

    fn n_layer_groups(&self) -> usize {
        self.cost.n_layers.div_ceil(self.safepoint_layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{Class, Phase};

    fn plan(preemptible: bool) -> IterationPlan {
        let mut p = IterationPlan {
            preemptible,
            ..Default::default()
        };
        p.push_item(1, Class::Offline, Phase::Prefill, 0, 512, &[]);
        p
    }

    fn backend() -> SimBackend {
        SimBackend::new(CostModel::a100_llama2_7b(), Clock::virtual_at(0), 8)
    }

    #[test]
    fn advances_clock_by_modelled_time() {
        let mut b = backend();
        let clock = b.clock();
        let out = b
            .execute(&plan(false), &mut |_| SafepointAction::Continue)
            .unwrap();
        assert!(out.completed);
        assert_eq!(out.safepoint_checks, 0); // non-preemptible: no safepoints
        assert_eq!(clock.now(), out.elapsed_us);
        let expect = CostModel::a100_llama2_7b().iter_us(512, 0, 0, 1);
        assert_eq!(out.elapsed_us, expect);
    }

    #[test]
    fn preemptible_pays_safepoint_cost() {
        let mut b = backend();
        let out = b
            .execute(&plan(true), &mut |_| SafepointAction::Continue)
            .unwrap();
        assert!(out.completed);
        assert_eq!(out.safepoint_checks, 3); // 32/8 groups -> 3 interior barriers
        let base = CostModel::a100_llama2_7b().iter_us(512, 0, 0, 1);
        assert_eq!(out.elapsed_us, base + 3 * 988);
    }

    #[test]
    fn abort_at_first_safepoint() {
        let mut b = backend();
        let out = b
            .execute(&plan(true), &mut |_| SafepointAction::Abort)
            .unwrap();
        assert!(!out.completed);
        assert_eq!(out.safepoint_checks, 1);
        let base = CostModel::a100_llama2_7b().iter_us(512, 0, 0, 1);
        // ran one of four groups plus one barrier
        assert!(out.elapsed_us < base / 2, "elapsed={}", out.elapsed_us);
    }

    #[test]
    fn abort_latency_bounded_by_group_time() {
        // responsiveness claim (§6.4.2): detection within ~one layer group
        let mut b = backend();
        let mut first_check_at = 0;
        let _ = b.execute(&plan(true), &mut |now| {
            first_check_at = now;
            SafepointAction::Abort
        });
        let base = CostModel::a100_llama2_7b().iter_us(512, 0, 0, 1);
        assert!(first_check_at <= base / 4 + 988 + 1);
    }
}
