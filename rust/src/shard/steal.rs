//! Cross-shard offline work stealing: checkpoint-backed migration of
//! queued offline requests from backlogged shards to idle ones.
//!
//! PR 3's shards share *nothing*, which is why they scale — and why a
//! shard that drew an offline burst sits on a deep backlog while its
//! neighbors idle: exactly the stranded capacity ConServe's harvesting
//! story exists to kill. The paper's incremental checkpointing (§4.4)
//! makes the fix cheap: a fully-checkpointed, GPU-evicted offline
//! request is *portable* — moving it is a host-side handoff (the
//! checkpoint accounting via
//! [`KvManager::export_host`](crate::kvcache::KvManager::export_host) /
//! `import_host`, plus the backend's host mirror via
//! [`ExecBackend::export_host_kv`](crate::backend::ExecBackend::export_host_kv))
//! and a target-side prefetch; no GPU state is touched. Requests that
//! never ran (or were discard-preempted) are *cold* steals: nothing but
//! the [`PortableRequest`] moves.
//!
//! ## Protocol (thief-initiated, mailbox per shard)
//!
//! 1. **Demand.** A shard whose offline backlog is at or below
//!    [`StealConfig::hungry_below`] posts a demand — one relaxed atomic
//!    store into the chosen donor's `wants` row. The donor is picked
//!    from the [`ShardLoads`] board: the deepest `offline_waiting`
//!    above [`StealConfig::min_donor_backlog`]. Demands are idempotent
//!    (a cell per thief, not a queue): re-posting while hungry cannot
//!    grow anything.
//! 2. **Fulfill.** Once per engine iteration the donor drains its
//!    demand row and, within [`StealConfig::budget_per_iter`], extracts
//!    victims from its offline queue **tail** (the work least likely to
//!    run there soon), detaches them
//!    ([`ServingEngine::donate_victims`](crate::server::ServingEngine::donate_victims)),
//!    and appends them to each thief's inbox.
//! 3. **Adopt.** The thief drains its inbox at the top of its next
//!    iteration
//!    ([`ServingEngine::absorb_migrations`](crate::server::ServingEngine::absorb_migrations)):
//!    each request is re-keyed into the thief's arena (fresh id carrying
//!    the thief's shard bits — the donor's old id is stale by generation
//!    *and* shard bits and can never resolve anywhere again),
//!    its checkpoint prefix is imported into the thief's host pool, and
//!    it joins the thief's offline queue; resume is a plain prefetch.
//!
//! `submitted_id` and `sampler_state` travel with the request, so result
//! correlation and token streams are invariant under migration (see
//! `tests/steal_props.rs`: the same trace with stealing on and off
//! completes the identical request set with identical token streams).
//!
//! ## Termination (free-running fleets)
//!
//! Engines on their own OS threads must not exit while a sibling might
//! still deliver work. A shard that drains its local work enters *idle*;
//! when every shard is idle and every inbox is empty, the fleet is
//! `finished()` and everyone exits. A shard forced out early (time cap)
//! `retire()`s: its inbox drains into an orphan pool that any live shard
//! adopts, so migrations are never silently dropped.

use crate::backend::HostKvBlob;
use crate::request::PortableRequest;
use crate::shard::ShardLoads;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Tuning knobs for the steal coordinator. The defaults favor smooth
/// trickle over bulk moves: a donor gives away at most `budget_per_iter`
/// requests per scheduling iteration, so migration cost stays bounded
/// and off the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StealConfig {
    /// Max requests one donor migrates per engine iteration (the
    /// per-iteration steal budget).
    pub budget_per_iter: usize,
    /// A donor only gives work away while its own offline backlog
    /// exceeds this floor (it keeps enough to stay saturated).
    pub min_donor_backlog: usize,
    /// A shard posts demands while its offline backlog is at or below
    /// this watermark.
    pub hungry_below: usize,
}

impl Default for StealConfig {
    fn default() -> Self {
        Self {
            budget_per_iter: 8,
            min_donor_backlog: 4,
            hungry_below: 1,
        }
    }
}

/// One offline request in flight between shards: the shard-portable
/// request plus the host KV payload of its checkpoint prefix (`None`
/// for cold steals and on the simulator, whose checkpoints are
/// accounting-only).
#[derive(Debug)]
pub struct MigratedRequest {
    pub portable: PortableRequest,
    pub kv: Option<HostKvBlob>,
}

/// Per-shard mailbox.
struct StealCell {
    /// `wants[t]`: requests thief `t` currently asks of this shard.
    /// Idempotent demand cells (stores, not pushes) — a hungry thief
    /// re-posting every iteration cannot grow state.
    wants: Vec<AtomicU64>,
    /// Migrations delivered to this shard, adopted at its next
    /// iteration (or poll, when it is idle-waiting).
    inbox: Mutex<Vec<MigratedRequest>>,
    /// Out of local work, waiting on deliveries or fleet termination.
    idle: AtomicBool,
    /// Permanently gone (time cap / run end): deliveries divert to the
    /// orphan pool.
    retired: AtomicBool,
}

/// The fleet-wide steal coordinator: one mailbox per shard, an
/// imbalance detector over the shared [`ShardLoads`] board, and the
/// idle/termination protocol. All operations are a few atomics or one
/// short mutex hold, and every engine touches it at most once per
/// iteration — nothing here is on a scheduling hot path.
pub struct StealCoordinator {
    cfg: StealConfig,
    loads: Arc<ShardLoads>,
    cells: Vec<StealCell>,
    /// Deliveries to retired shards, re-adopted by any live shard.
    orphans: Mutex<Vec<MigratedRequest>>,
    done: AtomicBool,
}

impl StealCoordinator {
    /// A coordinator over the shards of `loads` (one cell per shard).
    pub fn new(cfg: StealConfig, loads: Arc<ShardLoads>) -> Self {
        let n = loads.n_shards();
        Self {
            cfg,
            loads,
            cells: (0..n)
                .map(|_| StealCell {
                    wants: (0..n).map(|_| AtomicU64::new(0)).collect(),
                    inbox: Mutex::new(Vec::new()),
                    idle: AtomicBool::new(false),
                    retired: AtomicBool::new(false),
                })
                .collect(),
            orphans: Mutex::new(Vec::new()),
            done: AtomicBool::new(false),
        }
    }

    pub fn config(&self) -> &StealConfig {
        &self.cfg
    }

    pub fn n_shards(&self) -> usize {
        self.cells.len()
    }

    /// Imbalance detector: the donor with the deepest published offline
    /// backlog above the donor floor (ties: lowest index), or `None`
    /// when the board shows no surplus anywhere. Retired shards are
    /// skipped — their last published backlog is frozen (a time-capped
    /// donor dies mid-backlog) and a demand posted to a corpse would
    /// never be served, capturing the thief forever.
    pub fn pick_donor(&self, thief: usize) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for s in 0..self.cells.len() {
            if s == thief || self.cells[s].retired.load(Ordering::SeqCst) {
                continue;
            }
            let backlog = self.loads.snapshot(s).offline_waiting;
            if backlog as usize <= self.cfg.min_donor_backlog {
                continue;
            }
            if best.is_none_or(|(b, _)| backlog > b) {
                best = Some((backlog, s));
            }
        }
        best.map(|(_, s)| s)
    }

    /// Thief side: ask `donor` for up to `want` offline requests.
    /// Posting clears the thief's cells on every other donor, so
    /// switching donors (the previous one drained or died) does not
    /// leave a stale demand behind. Best-effort, not airtight: a donor
    /// that already `take_demands`-swapped the old demand into its
    /// local buffer will still serve it, so a thief can transiently
    /// receive up to two budgets' worth — bounded over-supply the
    /// donor floor then redistributes, never lost work.
    pub fn post_demand(&self, thief: usize, donor: usize, want: usize) {
        for (s, cell) in self.cells.iter().enumerate() {
            let w = if s == donor { want as u64 } else { 0 };
            cell.wants[thief].store(w, Ordering::Relaxed);
        }
    }

    /// Donor side: collect (and clear) the demands posted to `donor` as
    /// `(thief, want)` pairs, lowest thief index first.
    pub fn take_demands(&self, donor: usize, out: &mut Vec<(usize, usize)>) {
        out.clear();
        for (t, w) in self.cells[donor].wants.iter().enumerate() {
            let v = w.swap(0, Ordering::Relaxed);
            if v > 0 && t != donor {
                out.push((t, v as usize));
            }
        }
    }

    /// Donor side: append migrations to `thief`'s inbox (drains `migs`).
    /// Deliveries to a retired thief divert to the orphan pool so no
    /// request is ever dropped. The retired flag is checked *under the
    /// inbox lock* (and [`retire`](Self::retire) flips it under the same
    /// lock), so a delivery can never land in an inbox that a concurrent
    /// retire has already drained for the last time.
    pub fn deliver(&self, thief: usize, migs: &mut Vec<MigratedRequest>) {
        if migs.is_empty() {
            return;
        }
        let cell = &self.cells[thief];
        {
            let mut inbox = cell.inbox.lock().unwrap();
            if !cell.retired.load(Ordering::SeqCst) {
                inbox.append(migs);
                return;
            }
        }
        self.orphans.lock().unwrap().append(migs);
    }

    /// Target side: move deliveries into `out` (appends; does not clear).
    /// An empty inbox falls back to adopting orphans. Returns how many
    /// migrations were picked up. Adopting work clears the shard's idle
    /// flag *under the same lock* that empties the mailbox, so a
    /// concurrent termination check can never observe the emptied
    /// mailbox together with a stale idle flag (the check re-reads the
    /// flags after inspecting the mailboxes).
    pub fn drain_inbox(&self, shard: usize, out: &mut Vec<MigratedRequest>) -> usize {
        let before = out.len();
        let cell = &self.cells[shard];
        {
            let mut inbox = cell.inbox.lock().unwrap();
            if !inbox.is_empty() {
                cell.idle.store(false, Ordering::SeqCst);
                out.append(&mut inbox);
            }
        }
        if out.len() == before {
            let mut orphans = self.orphans.lock().unwrap();
            if !orphans.is_empty() {
                cell.idle.store(false, Ordering::SeqCst);
                out.append(&mut orphans);
            }
        }
        out.len() - before
    }

    /// `shard` has no local work and an exhausted arrival source; it now
    /// waits on deliveries. Sets the fleet-done flag when every shard is
    /// idle with nothing in flight.
    pub fn enter_idle(&self, shard: usize) {
        self.cells[shard].idle.store(true, Ordering::SeqCst);
        self.check_done();
    }

    /// `shard` adopted new work and is serving again.
    pub fn leave_idle(&self, shard: usize) {
        self.cells[shard].idle.store(false, Ordering::SeqCst);
    }

    /// All shards idle and every mailbox empty: nothing can create work
    /// anymore, the fleet may exit.
    pub fn finished(&self) -> bool {
        self.done.load(Ordering::SeqCst)
    }

    /// Permanently withdraw `shard` (run finished or time cap hit). Its
    /// pending demands are cancelled and its inbox drains into the
    /// orphan pool for any live shard to adopt. The retired flag flips
    /// under the inbox lock so it serializes with
    /// [`deliver`](Self::deliver): after this drain, no delivery can
    /// reach this inbox again.
    ///
    /// If *every* shard exits through a bound (duration cap, wall-clock
    /// failsafe) while migrations are still in flight, the leftovers
    /// stay in the orphan pool ([`orphan_count`](Self::orphan_count)) —
    /// visible as `steals_out > steals_in` in the merged recorder.
    /// Natural termination (`finished()`) guarantees the pool is empty;
    /// callers that assert request conservation should size their
    /// duration caps generously.
    pub fn retire(&self, shard: usize) {
        let cell = &self.cells[shard];
        let mut stranded = Vec::new();
        {
            let mut inbox = cell.inbox.lock().unwrap();
            cell.retired.store(true, Ordering::SeqCst);
            stranded.append(&mut inbox);
        }
        cell.idle.store(true, Ordering::SeqCst);
        for c in &self.cells {
            c.wants[shard].store(0, Ordering::Relaxed);
        }
        if !stranded.is_empty() {
            self.orphans.lock().unwrap().append(&mut stranded);
        }
        self.check_done();
    }

    fn check_done(&self) {
        let all_idle = || self.cells.iter().all(|c| c.idle.load(Ordering::SeqCst));
        if !all_idle() {
            return;
        }
        let empty = self
            .cells
            .iter()
            .all(|c| c.inbox.lock().unwrap().is_empty())
            && self.orphans.lock().unwrap().is_empty();
        // re-check the flags: a thief that emptied its mailbox after the
        // first flag pass cleared its idle flag under the mailbox lock
        // *before* the mailbox could read empty, so if every mailbox
        // read empty and every flag still reads idle, nothing is in
        // flight anywhere — the fleet is done.
        if empty && all_idle() {
            self.done.store(true, Ordering::SeqCst);
        }
    }

    /// Fault-injection hook (`drop-steals`, see [`crate::util::fault`]):
    /// route a prepared delivery straight into the orphan pool instead
    /// of the thief's inbox — models a dropped mailbox delivery without
    /// losing the requests, since any live shard's idle drain adopts
    /// orphans. Drains `migs`.
    pub fn divert_to_orphans(&self, migs: &mut Vec<MigratedRequest>) {
        if migs.is_empty() {
            return;
        }
        self.orphans.lock().unwrap().append(migs);
    }

    /// Orphaned migrations currently awaiting adoption (observability).
    pub fn orphan_count(&self) -> usize {
        self.orphans.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{Class, PortableRequest, Request};

    fn mig(submitted: u64) -> MigratedRequest {
        let mut r = Request::new(submitted, Class::Offline, vec![], 64, 8, 0);
        r.submitted_id = submitted;
        MigratedRequest {
            portable: PortableRequest::detach(r, 0),
            kv: None,
        }
    }

    fn coordinator(n: usize) -> (StealCoordinator, Arc<ShardLoads>) {
        let loads = Arc::new(ShardLoads::new(n, 1000));
        (
            StealCoordinator::new(StealConfig::default(), loads.clone()),
            loads,
        )
    }

    #[test]
    fn demands_are_idempotent_and_cleared_on_take() {
        let (st, _loads) = coordinator(3);
        st.post_demand(1, 0, 8);
        st.post_demand(1, 0, 8); // re-post while hungry: no growth
        st.post_demand(2, 0, 4);
        let mut out = Vec::new();
        st.take_demands(0, &mut out);
        assert_eq!(out, vec![(1, 8), (2, 4)]);
        st.take_demands(0, &mut out);
        assert!(out.is_empty(), "demands clear on take");
    }

    #[test]
    fn pick_donor_follows_published_backlog() {
        let (st, loads) = coordinator(4);
        assert_eq!(st.pick_donor(1), None, "no surplus published yet");
        loads.publish(0, 10, 0, 30, 30, 0);
        loads.publish(2, 10, 0, 90, 90, 0);
        loads.publish(3, 10, 0, 2, 2, 0); // at/below the donor floor
        assert_eq!(st.pick_donor(1), Some(2));
        assert_eq!(st.pick_donor(2), Some(0), "never picks itself");
    }

    #[test]
    fn deliver_drain_round_trip() {
        let (st, _loads) = coordinator(2);
        let mut migs = vec![mig(7), mig(8)];
        st.deliver(1, &mut migs);
        assert!(migs.is_empty(), "deliver drains the donor buffer");
        let mut inbox = Vec::new();
        assert_eq!(st.drain_inbox(1, &mut inbox), 2);
        assert_eq!(inbox.len(), 2);
        assert_eq!(st.drain_inbox(1, &mut inbox), 0);
    }

    #[test]
    fn termination_waits_for_inboxes() {
        let (st, _loads) = coordinator(2);
        st.enter_idle(0);
        assert!(!st.finished());
        let mut migs = vec![mig(1)];
        st.deliver(1, &mut migs);
        st.enter_idle(1);
        assert!(!st.finished(), "idle with a pending delivery is not done");
        let mut inbox = Vec::new();
        st.drain_inbox(1, &mut inbox);
        st.leave_idle(1);
        st.enter_idle(1);
        assert!(st.finished());
    }

    #[test]
    fn diverted_deliveries_survive_as_orphans() {
        let (st, _loads) = coordinator(2);
        let mut migs = vec![mig(11), mig(12)];
        st.divert_to_orphans(&mut migs);
        assert!(migs.is_empty(), "divert drains the buffer like deliver");
        assert_eq!(st.orphan_count(), 2);
        let mut inbox = Vec::new();
        assert_eq!(st.drain_inbox(1, &mut inbox), 2, "a live shard adopts them");
        assert_eq!(st.orphan_count(), 0);
    }

    #[test]
    fn retired_shard_strands_nothing() {
        let (st, _loads) = coordinator(3);
        let mut migs = vec![mig(9)];
        st.deliver(2, &mut migs);
        st.retire(2);
        assert_eq!(st.orphan_count(), 1, "inbox drained to orphans");
        // late delivery to a retired shard also diverts
        let mut late = vec![mig(10)];
        st.deliver(2, &mut late);
        assert_eq!(st.orphan_count(), 2);
        // a live shard adopts orphans when its own inbox is empty
        let mut inbox = Vec::new();
        assert_eq!(st.drain_inbox(0, &mut inbox), 2);
        assert_eq!(st.orphan_count(), 0);
    }
}
