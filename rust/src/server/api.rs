//! Request ingestion: the engine's two frontends (paper §4.1).
//!
//! * **Trace source** — pre-generated timestamped requests; the engine
//!   makes them visible as the (virtual or wall) clock passes their
//!   arrival times. This drives every benchmark deterministically.
//! * **Channel source** — a live `EngineClient` handle: the real-time
//!   streaming path (`submit_online`) and the OpenAI-Batch-style path
//!   (`submit_batch`). Producers run on their own threads; the engine
//!   polls between iterations and at safepoints, which is exactly where
//!   the paper's async arrival handler fires.
//!
//! # Fail-fast semantics under shard loss
//!
//! Online submissions are **not** durable: if the shard a request was
//! routed to dies (see [`crate::shard::supervisor`]), the request is
//! reported in
//! [`JobRunOutcome::failed_online`](crate::batch::JobRunOutcome::failed_online)
//! as a structured fail-fast set and the client is expected to retry —
//! resubmission mints a fresh ticket, so a retry can never collide with
//! the lost request's id. On the live HTTP path
//! ([`crate::server::http`]) the same set surfaces as a structured
//! `503` body carrying the failed request ids and a retry hint, so
//! network clients can implement this contract without scraping logs.
//! Offline *job* work takes the opposite contract: specs and periodic
//! checkpoints live in the durable
//! [`JobStore`](crate::batch::JobStore), and crash recovery
//! ([`crate::batch::run_jobs_with_recovery`]) replays it with the same
//! submission ids, so keyed sampling regenerates byte-identical
//! streams instead of asking the submitter to retry.
//!
//! # Backpressure, shedding and drain
//!
//! The submission channel is **bounded** ([`SUBMIT_CHANNEL_CAP`]): a
//! producer that outruns the engine blocks (`submit_*`) or gets
//! [`SubmitError::Full`] (`try_submit_*`) instead of growing an
//! unbounded queue. Above the channel, the front door's admission
//! controller ([`crate::server::admission`]) sheds work *before* it is
//! submitted — shed requests receive a structured `429` with a
//! `Retry-After` hint, offline load is shed first, and a draining
//! server answers `503` with `"draining"` — so a request that makes it
//! into this channel has been *accepted*: graceful drain
//! ([`ServingEngine::set_drain_flag`](super::ServingEngine::set_drain_flag))
//! finishes accepted online work and checkpoints accepted offline work
//! to the `JobStore` rather than dropping either.

use crate::batch::{JobBoard, JobProgress};
use crate::request::{Class, Request, RequestId, TokenId};
use crate::TimeUs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError, TrySendError};
use std::sync::Arc;

/// Default bound of the live submission channel. Deep enough that a
/// normal burst never blocks (the engine drains arrivals every
/// iteration), shallow enough that a runaway producer is backpressured
/// in ~requests, not in memory.
pub const SUBMIT_CHANNEL_CAP: usize = 4096;

/// Why a non-blocking submission was refused.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded submission channel is at capacity: the engine is not
    /// draining arrivals fast enough. Shed or retry after a backoff.
    Full,
    /// The serving engine is gone (its arrival source was dropped).
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Full => write!(f, "submission channel full (engine backlogged)"),
            SubmitError::Closed => write!(f, "serving engine gone (channel closed)"),
        }
    }
}

pub enum ArrivalSource {
    Trace {
        /// Sorted by arrival time.
        events: Vec<Request>,
        idx: usize,
    },
    Channel {
        rx: Receiver<Request>,
        peeked: Option<Request>,
        closed: bool,
    },
}

impl ArrivalSource {
    pub fn from_trace(mut events: Vec<Request>) -> Self {
        events.sort_by_key(|r| r.arrival);
        ArrivalSource::Trace { events, idx: 0 }
    }

    pub fn channel() -> (EngineClient, Self) {
        Self::channel_shared(Arc::new(AtomicU64::new(1)))
    }

    /// Channel source whose client draws tickets from `next_id`. Sharded
    /// frontends pass one shared counter to every shard's client so
    /// tickets stay globally unique across shards (see
    /// [`sharded_channel`](crate::shard::sharded_channel)).
    pub fn channel_shared(next_id: Arc<AtomicU64>) -> (EngineClient, Self) {
        Self::channel_with_board(next_id, Arc::new(JobBoard::new()))
    }

    /// Channel source with an explicit shared [`JobBoard`]: sharded
    /// frontends pass one board to every shard's client so a batch job
    /// spanning shards still reports unified progress. Attach the same
    /// board to each engine
    /// ([`ServingEngine::set_job_board`](super::ServingEngine::set_job_board))
    /// or batch progress will never advance.
    pub fn channel_with_board(
        next_id: Arc<AtomicU64>,
        jobs: Arc<JobBoard>,
    ) -> (EngineClient, Self) {
        Self::channel_with_board_cap(next_id, jobs, SUBMIT_CHANNEL_CAP)
    }

    /// [`channel_with_board`](Self::channel_with_board) with an explicit
    /// channel bound (tests use tiny caps to exercise backpressure).
    pub fn channel_with_board_cap(
        next_id: Arc<AtomicU64>,
        jobs: Arc<JobBoard>,
        cap: usize,
    ) -> (EngineClient, Self) {
        let (tx, rx) = sync_channel(cap.max(1));
        (
            EngineClient { tx, next_id, jobs },
            ArrivalSource::Channel {
                rx,
                peeked: None,
                closed: false,
            },
        )
    }

    /// All requests with arrival <= now (allocating convenience wrapper
    /// over [`poll_each`](Self::poll_each)).
    pub fn poll(&mut self, now: TimeUs) -> Vec<Request> {
        let mut out = Vec::new();
        self.poll_each(now, &mut |r| out.push(r));
        out
    }

    /// Deliver each request with arrival <= now to `f`. The engine's
    /// per-iteration arrival drain uses this — no per-poll vector on the
    /// hot path (the common case delivers nothing).
    pub fn poll_each(&mut self, now: TimeUs, f: &mut dyn FnMut(Request)) {
        match self {
            ArrivalSource::Trace { events, idx } => {
                while *idx < events.len() && events[*idx].arrival <= now {
                    f(events[*idx].clone());
                    *idx += 1;
                }
            }
            ArrivalSource::Channel { rx, peeked, closed } => {
                if let Some(r) = peeked.take_if(|r| r.arrival <= now) {
                    f(r);
                }
                if peeked.is_none() {
                    loop {
                        match rx.try_recv() {
                            Ok(mut r) => {
                                // live submissions are stamped on receipt
                                if r.arrival == 0 {
                                    r.arrival = now;
                                }
                                if r.arrival <= now {
                                    f(r);
                                } else {
                                    *peeked = Some(r);
                                    break;
                                }
                            }
                            Err(TryRecvError::Empty) => break,
                            Err(TryRecvError::Disconnected) => {
                                *closed = true;
                                break;
                            }
                        }
                    }
                }
            }
        }
    }

    /// Next known arrival time (virtual-clock jump target).
    pub fn next_time(&self) -> Option<TimeUs> {
        match self {
            ArrivalSource::Trace { events, idx } => events.get(*idx).map(|r| r.arrival),
            ArrivalSource::Channel { peeked, .. } => peeked.as_ref().map(|r| r.arrival),
        }
    }

    pub fn exhausted(&self) -> bool {
        match self {
            ArrivalSource::Trace { events, idx } => *idx >= events.len(),
            ArrivalSource::Channel { closed, peeked, .. } => *closed && peeked.is_none(),
        }
    }

    /// Real-clock idle nap (channel mode): block briefly for an arrival.
    pub fn wait_a_moment(&mut self) {
        if let ArrivalSource::Channel { rx, peeked, closed } = self {
            if peeked.is_none() && !*closed {
                match rx.recv_timeout(std::time::Duration::from_micros(500)) {
                    Ok(r) => *peeked = Some(r),
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => *closed = true,
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                }
            }
        } else {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
}

/// Tickets live in their own id namespace (high bit set) so a ticket
/// can never alias an arena [`RequestId`] — indexing the engine table
/// with a ticket misses loudly instead of silently reading another
/// request's state.
pub const CLIENT_TICKET_BIT: u64 = 1 << 63;

/// Cloneable submission handle (thread-safe).
///
/// Returned ids are *submission tickets*: unique per client but distinct
/// from engine arena ids (the engine re-keys every request into its slab
/// arena on admission). The ticket is preserved as
/// [`Request::submitted_id`], so correlate results by matching that
/// field — e.g. `engine.table.values().find(|r| r.submitted_id == ticket)`.
#[derive(Clone)]
pub struct EngineClient {
    tx: SyncSender<Request>,
    next_id: Arc<AtomicU64>,
    /// Batch-job progress board shared with the serving engine(s); see
    /// [`BatchHandle`].
    jobs: Arc<JobBoard>,
}

/// Handle to a submitted batch job: the per-request tickets plus a
/// poll-able progress snapshot — the status surface `submit_batch` used
/// to lack. Progress advances when the engine(s) serving this client
/// share its [`JobBoard`]
/// ([`ServingEngine::set_job_board`](super::ServingEngine::set_job_board));
/// callers throttle on it instead of firing and forgetting:
/// `while !h.progress().done() { ... }`. The handle owns its progress
/// cell, so it stays valid even after the board garbage-collects the
/// completed job ([`JobBoard::gc_completed`]).
#[derive(Clone)]
pub struct BatchHandle {
    /// Job id under which the members were stamped (the engine-side
    /// correlation key, [`Request::job`]).
    pub job: u64,
    /// Submission tickets, one per member, in submission order.
    pub tickets: Vec<RequestId>,
    cell: Arc<crate::batch::JobCell>,
}

impl BatchHandle {
    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }

    pub fn ids(&self) -> &[RequestId] {
        &self.tickets
    }

    /// Poll the job's progress (total / finished / generated tokens /
    /// completion). Lock-free: a few relaxed atomic loads on the
    /// handle-owned cell.
    pub fn progress(&self) -> JobProgress {
        self.cell.snapshot()
    }

    /// All members finished?
    pub fn done(&self) -> bool {
        self.progress().done()
    }
}

impl EngineClient {
    /// Mint a ticket and construct the request without sending it. The
    /// split lets non-blocking submitters (`try_submit_*`) and the
    /// recorded-job path build first, then choose how to send.
    fn build_stamped(
        &self,
        class: Class,
        prompt: Vec<TokenId>,
        max_new_tokens: usize,
        stamp: impl FnOnce(&mut Request),
    ) -> Request {
        let id = CLIENT_TICKET_BIT | self.next_id.fetch_add(1, Ordering::Relaxed);
        let len = prompt.len();
        // arrival == 0 => stamped by the engine on receipt
        let mut req = Request::new(id, class, prompt, len, max_new_tokens, 0);
        stamp(&mut req);
        req
    }

    /// Blocking send: backpressures the caller when the bounded channel
    /// is full instead of growing memory.
    pub(crate) fn send(&self, req: Request) {
        let _ = self.tx.send(req);
    }

    /// Non-blocking send. On `Full` the request is dropped here (the
    /// ticket was never observable by the engine, so no state leaks) and
    /// the caller sheds or retries.
    pub(crate) fn try_send(&self, req: Request) -> Result<(), SubmitError> {
        match self.tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => Err(SubmitError::Full),
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    fn submit_stamped(
        &self,
        class: Class,
        prompt: Vec<TokenId>,
        max_new_tokens: usize,
        stamp: impl FnOnce(&mut Request),
    ) -> RequestId {
        let req = self.build_stamped(class, prompt, max_new_tokens, stamp);
        let id = req.id;
        self.send(req);
        id
    }

    fn submit(&self, class: Class, prompt: Vec<TokenId>, max_new_tokens: usize) -> RequestId {
        self.submit_stamped(class, prompt, max_new_tokens, |_| {})
    }

    /// Non-blocking [`submit_online`](Self::submit_online): refuses with
    /// [`SubmitError::Full`] instead of blocking when the engine is
    /// backlogged. The front door uses this so a slow engine turns into
    /// a structured shed, never a stuck accept thread.
    pub fn try_submit_online(
        &self,
        prompt: Vec<TokenId>,
        max_new_tokens: usize,
    ) -> Result<RequestId, SubmitError> {
        let req = self.build_stamped(Class::Online, prompt, max_new_tokens, |_| {});
        let id = req.id;
        self.try_send(req)?;
        Ok(id)
    }

    /// Non-blocking [`submit_offline`](Self::submit_offline).
    pub fn try_submit_offline(
        &self,
        prompt: Vec<TokenId>,
        max_new_tokens: usize,
    ) -> Result<RequestId, SubmitError> {
        let req = self.build_stamped(Class::Offline, prompt, max_new_tokens, |_| {});
        let id = req.id;
        self.try_send(req)?;
        Ok(id)
    }

    /// The job-progress board this client registers batches on. Attach
    /// a clone to every engine serving this client's requests.
    pub fn job_board(&self) -> &Arc<JobBoard> {
        &self.jobs
    }

    /// Real-time streaming API: one latency-critical request.
    pub fn submit_online(&self, prompt: Vec<TokenId>, max_new_tokens: usize) -> RequestId {
        self.submit(Class::Online, prompt, max_new_tokens)
    }

    /// Batch API, single request: one best-effort request (the sharded
    /// client places batch members on different shards one by one).
    pub fn submit_offline(&self, prompt: Vec<TokenId>, max_new_tokens: usize) -> RequestId {
        self.submit(Class::Offline, prompt, max_new_tokens)
    }

    /// Batch API: a pool of best-effort requests under one anonymous
    /// job (default tenant, no deadline). Returns a [`BatchHandle`]
    /// whose progress the serving engine advances.
    ///
    /// Every batch registers one board cell. Wire the board to the
    /// serving engine(s) (`engine.set_job_board(client.job_board()
    /// .clone())`) or progress never advances and the cell can never
    /// complete; a long-lived submitter that does not wire (or that
    /// abandons batches) should bound the board with
    /// `job_board().retire(handle.job)` /
    /// [`gc_completed`](JobBoard::gc_completed).
    pub fn submit_batch(&self, prompts: Vec<(Vec<TokenId>, usize)>) -> BatchHandle {
        self.submit_job(prompts, 0, 0, 0)
    }

    /// Batch API with job identity: `tenant`, `urgency` (EDF score, see
    /// [`crate::batch::urgency_score`]) and a soft `deadline` (µs
    /// timestamp, 0 = none) stamp every member, feeding the fair-share
    /// pick order and urgency-aware stealing on the serving side.
    pub fn submit_job(
        &self,
        prompts: Vec<(Vec<TokenId>, usize)>,
        tenant: u32,
        urgency: u32,
        deadline: TimeUs,
    ) -> BatchHandle {
        let job = self.register_job(prompts.len() as u64, tenant, deadline);
        let tickets = prompts
            .into_iter()
            .map(|(p, n)| self.submit_job_member(job, tenant, urgency, deadline, p, n))
            .collect();
        self.handle(job, tickets)
    }

    /// Allocate + register a job on this client's board. Job ids share
    /// the ticket counter: unique against every other job from any
    /// clone (the ticket bit stays clear — jobs are not request ids).
    /// Sharded frontends register once here, then place members shard
    /// by shard with [`submit_job_member`](Self::submit_job_member).
    pub(crate) fn register_job(&self, total: u64, tenant: u32, deadline: TimeUs) -> u64 {
        let job = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.jobs.register(job, total, deadline, tenant);
        job
    }

    /// Build (without sending) one member of an already-registered job,
    /// stamped with the full durable-job identity including the
    /// fair-share weight. The prepared-job path
    /// ([`ShardedClient::prepare_job`](crate::shard::ShardedClient::prepare_job))
    /// persists the built requests into the `JobStore` spec before
    /// dispatching, so a drain checkpoint can rebuild them
    /// byte-identically.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build_job_member(
        &self,
        job: u64,
        tenant: u32,
        urgency: u32,
        deadline: TimeUs,
        fair_weight: u32,
        prompt: Vec<TokenId>,
        max_new_tokens: usize,
    ) -> Request {
        self.build_stamped(Class::Offline, prompt, max_new_tokens, |r| {
            r.job = job;
            r.tenant = tenant;
            r.urgency = urgency;
            r.deadline = deadline;
            r.fair_weight = fair_weight;
        })
    }

    /// Submit one member of an already-registered job.
    pub(crate) fn submit_job_member(
        &self,
        job: u64,
        tenant: u32,
        urgency: u32,
        deadline: TimeUs,
        prompt: Vec<TokenId>,
        max_new_tokens: usize,
    ) -> RequestId {
        self.submit_stamped(Class::Offline, prompt, max_new_tokens, |r| {
            r.job = job;
            r.tenant = tenant;
            r.urgency = urgency;
            r.deadline = deadline;
        })
    }

    /// Build a handle over this client's board for a registered job.
    pub(crate) fn handle(&self, job: u64, tickets: Vec<RequestId>) -> BatchHandle {
        BatchHandle {
            job,
            tickets,
            cell: self
                .jobs
                .cell(job)
                .expect("handle() is only called for jobs registered on this board"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: RequestId, at: TimeUs) -> Request {
        Request::new(id, Class::Online, vec![], 8, 2, at)
    }

    #[test]
    fn trace_source_releases_in_time_order() {
        let mut src = ArrivalSource::from_trace(vec![req(2, 200), req(1, 100), req(3, 300)]);
        assert_eq!(src.next_time(), Some(100));
        let got = src.poll(150);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].id, 1);
        assert_eq!(src.next_time(), Some(200));
        assert_eq!(src.poll(1000).len(), 2);
        assert!(src.exhausted());
    }

    #[test]
    fn channel_source_stamps_arrivals() {
        let (client, mut src) = ArrivalSource::channel();
        client.submit_online(vec![1, 2, 3], 4);
        client.submit_batch(vec![(vec![4], 2), (vec![5], 2)]);
        let got = src.poll(777);
        assert_eq!(got.len(), 3);
        assert!(got.iter().all(|r| r.arrival == 777));
        assert_eq!(got[0].class, Class::Online);
        assert_eq!(got[1].class, Class::Offline);
        assert!(!src.exhausted());
        drop(client);
        let _ = src.poll(778);
        assert!(src.exhausted());
    }

    #[test]
    fn batch_handle_polls_progress() {
        let (client, mut src) = ArrivalSource::channel();
        let h = client.submit_batch(vec![(vec![1], 2), (vec![2], 3)]);
        assert_eq!(h.len(), 2);
        assert!(!h.done());
        let p = h.progress();
        assert_eq!((p.total, p.finished), (2, 0));
        // members arrive stamped with the job id
        let got = src.poll(5);
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|r| r.job == h.job && r.class == Class::Offline));
        // engine-side completion notifications drive the handle
        assert!(client.job_board().note_finished(h.job, 2, 10).is_none());
        assert!(client.job_board().note_finished(h.job, 3, 11).is_some());
        assert!(h.done());
        assert_eq!(h.progress().met_deadline(), None, "deadline-free job");
        // the handle owns its cell: board gc does not invalidate it
        assert_eq!(client.job_board().gc_completed(), 1);
        assert!(h.done());
        assert_eq!(h.progress().gen_tokens, 5);
    }

    #[test]
    fn submit_job_stamps_identity() {
        let (client, mut src) = ArrivalSource::channel();
        let h = client.submit_job(vec![(vec![1], 2)], 7, 500, 123_456);
        let got = src.poll(1);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].tenant, 7);
        assert_eq!(got[0].urgency, 500);
        assert_eq!(got[0].deadline, 123_456);
        assert_eq!(got[0].job, h.job);
        // job ids live outside the ticket namespace; tickets stay in it
        assert_eq!(h.job & CLIENT_TICKET_BIT, 0);
        assert!(h.tickets.iter().all(|&t| t & CLIENT_TICKET_BIT != 0));
        // a second batch from a clone gets a distinct job id
        let h2 = client.clone().submit_batch(vec![(vec![3], 1)]);
        assert_ne!(h.job, h2.job);
    }

    #[test]
    fn client_tickets_never_alias_arena_ids() {
        use crate::request::RequestArena;
        let (client, mut src) = ArrivalSource::channel();
        let ticket = client.submit_online(vec![1, 2], 4);
        let mut arena = RequestArena::new();
        let mut id = 0;
        src.poll_each(1, &mut |req| {
            assert_eq!(req.submitted_id, ticket);
            id = arena.insert(req);
        });
        assert_ne!(id, ticket);
        // a ticket misses the arena instead of resolving to another
        // request's slot (distinct id namespaces)
        assert!(arena.get(ticket).is_none());
        // ...and the preserved submitted_id is the correlation path
        assert_eq!(arena[id].submitted_id, ticket);
    }

    #[test]
    fn bounded_channel_backpressures_bursts() {
        use crate::batch::JobBoard;
        // cap 2: the third non-blocking submit must shed, not grow memory
        let (client, mut src) = ArrivalSource::channel_with_board_cap(
            Arc::new(AtomicU64::new(1)),
            Arc::new(JobBoard::new()),
            2,
        );
        assert!(client.try_submit_online(vec![1], 1).is_ok());
        assert!(client.try_submit_offline(vec![2], 1).is_ok());
        assert_eq!(client.try_submit_online(vec![3], 1), Err(SubmitError::Full));
        // the engine draining arrivals frees credit for the next burst
        assert_eq!(src.poll(10).len(), 2);
        let t = client.try_submit_online(vec![4], 1).expect("credit freed");
        assert!(t & CLIENT_TICKET_BIT != 0);
        // channel gone => Closed, not Full
        drop(src);
        assert_eq!(
            client.try_submit_online(vec![5], 1),
            Err(SubmitError::Closed)
        );
    }

    #[test]
    fn client_ids_unique_across_clones() {
        let (client, mut src) = ArrivalSource::channel();
        let c2 = client.clone();
        let a = client.submit_online(vec![1], 1);
        let b = c2.submit_online(vec![2], 1);
        assert_ne!(a, b);
        assert_eq!(src.poll(1).len(), 2);
    }
}
