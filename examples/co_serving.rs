//! End-to-end validation driver (DESIGN.md "E2E"): live co-serving of a
//! real model on the CPU PJRT runtime.
//!
//! * a loadgen thread submits **online** requests through the streaming
//!   API following a gamma process (rate/CV configurable via env);
//! * a second thread drops an **offline** document pool into the batch
//!   API at t=0 (and a second wave mid-run);
//! * the engine co-serves both with ConServe's full machinery — SLO-aware
//!   budgets, preemption, incremental checkpointing, prefetching — and
//!   the driver reports TTFT/TPOT/throughput plus KV/preemption counters.
//!
//! Results are recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example co_serving
//! DURATION=30 RATE=3 cargo run --release --example co_serving
//! ```

use conserve::backend::PjrtBackend;
use conserve::config::EngineConfig;
use conserve::profiler::LatencyProfile;
use conserve::request::Class;
use conserve::runtime::tokenizer::detokenize;
use conserve::server::{ArrivalSource, ServingEngine};
use conserve::util::rng::Rng;
use conserve::workload::{datasets, LoadGen, Lengths};
use std::time::Duration;

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    let duration_s = env_f64("DURATION", 20.0);
    let rate = env_f64("RATE", 2.0);
    let cv = env_f64("CV", 1.5);
    let offline_pool = env_f64("OFFLINE_POOL", 24.0) as usize;

    let cfg = EngineConfig::real_tiny();
    let mut backend = PjrtBackend::load("artifacts", cfg.seed, cfg.sched.safepoint_layers)?;
    let clock = backend.clock();

    println!("profiling the PJRT backend (offline pass, §4.5) ...");
    let profile = LatencyProfile::profile(&mut backend, 128, 8, 128)?;
    println!("  t(µs) = {:.0} + {:.1}*prefill_tok + {:.0}*decode_seq + {:.2}*ctx_tok",
        profile.c[0], profile.c[1], profile.c[2], profile.c[3]);

    let (client, arrivals) = ArrivalSource::channel();

    // --- online loadgen thread: gamma arrivals, streaming API ---
    let online_client = client.clone();
    let online = std::thread::spawn(move || {
        let mut rng = Rng::new(0xA11CE);
        let mut lg = LoadGen::new(0xA11CE, rate, cv);
        let mut sent = 0usize;
        let t0 = std::time::Instant::now();
        loop {
            let next = lg.pop();
            let elapsed = t0.elapsed().as_micros() as u64;
            if next as f64 / 1e6 > duration_s {
                break;
            }
            if next > elapsed {
                std::thread::sleep(Duration::from_micros(next - elapsed));
            }
            let l = Lengths::online_tiny().sample(&mut rng);
            let prompt = datasets::synth_prompt(&mut rng, l.input);
            online_client.submit_online(prompt, l.output);
            sent += 1;
        }
        sent
    });

    // --- offline batch thread: pool at t=0, second wave mid-run ---
    let offline_client = client.clone();
    let offline = std::thread::spawn(move || {
        let mut rng = Rng::new(0xB0B);
        let make_pool = |rng: &mut Rng, n: usize| {
            (0..n)
                .map(|_| {
                    let l = Lengths::offline_tiny().sample(rng);
                    (datasets::synth_prompt(rng, l.input), l.output)
                })
                .collect::<Vec<_>>()
        };
        let ids1 = offline_client.submit_batch(make_pool(&mut rng, offline_pool));
        std::thread::sleep(Duration::from_secs_f64(duration_s / 2.0));
        let ids2 = offline_client.submit_batch(make_pool(&mut rng, offline_pool / 2));
        ids1.len() + ids2.len()
    });
    drop(client); // engine stops when producers hang up and work drains

    let mut engine = ServingEngine::new(cfg.clone(), backend, clock, profile, arrivals);
    let end = engine.run((duration_s * 2.5 * 1e6) as u64);
    let n_online = online.join().unwrap();
    let n_offline = offline.join().unwrap();

    // --- report ---
    let rec = &engine.rec;
    let dur = end.max(1);
    println!("\n=== co-serving run: {n_online} online + {n_offline} offline requests over {:.1}s wall ===",
        end as f64 / 1e6);
    println!("online  P99 TTFT {:>8.1} ms   (SLO {})", rec.p99_ttft_ms(Class::Online), cfg.sched.slo.ttft_ms);
    println!("online  P99 TPOT {:>8.1} ms   (SLO {})", rec.p99_tpot_ms(Class::Online), cfg.sched.slo.tpot_ms);
    println!("online  mean TTFT{:>8.1} ms", rec.mean_ttft_ms(Class::Online));
    println!("gen tput   {:>7.1} tok/s online, {:>7.1} tok/s offline",
        rec.throughput(Some(Class::Online), 0, dur),
        rec.throughput(Some(Class::Offline), 0, dur));
    println!("proc tput  {:>7.1} tok/s online, {:>7.1} tok/s offline",
        rec.processed_throughput(Some(Class::Online), 0, dur),
        rec.processed_throughput(Some(Class::Offline), 0, dur));
    println!("finished   {} online / {} offline", rec.finished[0], rec.finished[1]);
    println!("preemptions {} (layer aborts {}), ckpt blocks {}, prefetch blocks {}",
        rec.preemptions, rec.layer_aborts, rec.ckpt_blocks, rec.prefetch_blocks);

    if let Some(r) = engine
        .table
        .values()
        .find(|r| r.class == Class::Online && r.output.len() > 4)
    {
        println!("\nsample online completion (req {}):", r.id);
        println!("  prompt : {:?}", detokenize(&r.prompt[..r.prompt.len().min(60)]));
        println!("  output : {:?}", detokenize(&r.output));
    }

    // E2E validation gates: all layers composed, both classes served
    assert!(rec.finished[0] > 0, "online requests must complete");
    assert!(rec.finished[1] > 0, "offline requests must complete");
    assert!(
        rec.ttfts.iter().any(|e| e.class == Class::Online),
        "online TTFTs recorded"
    );
    println!("\nco_serving E2E OK");
    Ok(())
}
