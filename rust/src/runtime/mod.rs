//! Runtime support for the real serving path: AOT artifact loading
//! (manifest, weights, HLO executables), the byte-level tokenizer, and
//! token sampling.

pub mod artifacts;
pub mod sampler;
pub mod tokenizer;

pub use artifacts::{Artifacts, ModelDims};
pub use sampler::Sampler;
pub use tokenizer::{detokenize, tokenize};
