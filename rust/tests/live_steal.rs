//! Channel-mode work-stealing wiring: a threaded live fleet behind a
//! [`ShardedClient`] serves a deliberately skewed workload (the whole
//! offline burst enters through shard 0's per-shard client — one
//! tenant's dedicated ingress) with stealing on and off, and must
//! complete the identical request set either way, with the idle shard
//! demonstrably absorbing migrated work when stealing is on.
//!
//! This exercises the engine-generic steal hooks (`poll_steals` /
//! `post_hunger` / `drained` and the idle/retire termination protocol)
//! over *live* channel arrival sources — the path `run_sharded_traces`
//! never touches.

use conserve::backend::{CostModel, SimBackend};
use conserve::clock::Clock;
use conserve::config::EngineConfig;
use conserve::profiler::LatencyProfile;
use conserve::request::State;
use conserve::server::ServingEngine;
use conserve::shard::{sharded_channel, Placement, StealConfig, StealCoordinator};
use std::collections::BTreeMap;
use std::sync::Arc;

fn profile() -> LatencyProfile {
    LatencyProfile {
        c: [1200.0, 96.0, 40.0, 0.385],
    }
}

const N_SHARDS: usize = 2;
const UNTIL: u64 = 600_000_000; // generous virtual cap

/// (submitted_id -> generated) over every finished request, plus the
/// fleet's steal counters.
fn live_run(steal: bool) -> (BTreeMap<u64, usize>, u64, u64) {
    let cfg = EngineConfig::sim_a100_7b();
    let (client, loads, sources) = sharded_channel(N_SHARDS, Placement::affinity(), &cfg);
    let st = steal.then(|| {
        Arc::new(StealCoordinator::new(
            StealConfig::default(),
            loads.clone(),
        ))
    });

    // Submit everything up front, then hang up: the completed set is
    // then identical across runs regardless of thread interleaving.
    let mut expected = Vec::new();
    for _ in 0..8 {
        let t = client.submit_online(vec![1; 64], 4);
        assert!(t.shard < N_SHARDS);
        expected.push(t.ticket);
    }
    // entry-point skew: the whole offline burst through shard 0. Each
    // request is memory-heavy (~129 KV blocks of the 3072-block pool),
    // so shard 0 can only run ~24 at once and a real backlog persists —
    // the signal that makes shard 1 hungry enough to steal.
    let burst = client
        .client(0)
        .submit_batch(vec![(vec![2; 2048], 8); 40]);
    expected.extend_from_slice(burst.ids());
    drop(client);

    let results: Vec<(BTreeMap<u64, usize>, u64, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = sources
            .into_iter()
            .enumerate()
            .map(|(shard, src)| {
                let cfg = cfg.clone();
                let loads = loads.clone();
                let st = st.clone();
                scope.spawn(move || {
                    let clock = Clock::virtual_at(0);
                    let backend = SimBackend::new(
                        CostModel::a100_llama2_7b(),
                        clock.clone(),
                        cfg.sched.safepoint_layers,
                    );
                    let mut engine = ServingEngine::for_shard(
                        shard,
                        cfg.clone(),
                        backend,
                        clock,
                        profile(),
                        src,
                    );
                    engine.set_shard_loads(loads);
                    if let Some(st) = &st {
                        engine.set_steal_coordinator(st.clone());
                    }
                    match &st {
                        Some(st) => {
                            // the fleet idle/retire protocol, over live
                            // channel sources
                            let started = std::time::Instant::now();
                            'serve: loop {
                                engine.run(UNTIL);
                                if !engine.drained() {
                                    break; // time cap with work admitted
                                }
                                if engine.poll_steals() {
                                    continue;
                                }
                                st.enter_idle(shard);
                                loop {
                                    if st.finished() {
                                        break 'serve;
                                    }
                                    if engine.poll_steals() {
                                        st.leave_idle(shard);
                                        continue 'serve;
                                    }
                                    engine.post_hunger();
                                    if started.elapsed()
                                        > std::time::Duration::from_secs(30)
                                    {
                                        break 'serve; // never hang the test
                                    }
                                    std::thread::sleep(
                                        std::time::Duration::from_micros(50),
                                    );
                                }
                            }
                            st.retire(shard);
                        }
                        None => {
                            engine.run(UNTIL);
                        }
                    }
                    assert!(engine.kv.check_conservation(), "shard {shard}");
                    let mut fins = BTreeMap::new();
                    for r in engine.table.values() {
                        if r.state == State::Finished {
                            let prev = fins.insert(r.submitted_id, r.generated);
                            assert!(prev.is_none(), "request finished twice on one shard");
                        }
                    }
                    (fins, engine.rec.steals_in, engine.rec.steals_out)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("shard thread panicked"))
            .collect()
    });

    let mut all = BTreeMap::new();
    let (mut steals_in, mut steals_out) = (0, 0);
    for (fins, si, so) in results {
        for (sid, gen) in fins {
            let prev = all.insert(sid, gen);
            assert!(prev.is_none(), "request {sid} finished on two shards");
        }
        steals_in += si;
        steals_out += so;
    }
    assert_eq!(all.len(), expected.len(), "every submission completes");
    for sid in expected {
        assert!(all.contains_key(&sid), "submission {sid} lost");
    }
    (all, steals_in, steals_out)
}

#[test]
fn live_sharded_client_steal_on_off_equivalence() {
    let (off, off_in, _off_out) = live_run(false);
    let (on, on_in, on_out) = live_run(true);
    assert_eq!(off_in, 0, "no coordinator, no steals");
    assert!(on_in > 0, "the skewed live burst must trigger migrations");
    assert_eq!(on_in, on_out, "every migration adopted exactly once");
    assert_eq!(
        off, on,
        "stealing must not change which requests complete or their lengths"
    );
}
