use conserve::config::EngineConfig;
use conserve::report::compare_policies;
use conserve::scheduler::Policy;
use conserve::workload::trace::burstgpt_like_arrivals;
use conserve::workload::Lengths;
fn main() {
    let cfg = EngineConfig::sim_a100_7b();
    let arrivals = burstgpt_like_arrivals(42, 450.0, 1.2, 1.0);
    let rs = compare_policies(&cfg, &[Policy::ConServe], &arrivals,
        Lengths::online_paper(), |_| 1500, Lengths::offline_paper(), 450.0);
    println!("{}", rs[0].row());
}
