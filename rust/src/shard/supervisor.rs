//! Fleet supervision: panic isolation for shard workers, death
//! bookkeeping, and the heartbeat protocol over [`ShardLoads`]
//! sequence numbers.
//!
//! A shard worker that panics must not take the fleet down with it
//! (the pre-supervision runner joined with `.expect`, so one death
//! poisoned every caller) — and it must not strand work either: its
//! steal mailbox may hold migrated requests no other shard knows
//! about, and the termination protocol waits on its idle flag forever
//! if nobody retires it. The supervisor closes both holes:
//!
//! * Workers run inside `catch_unwind`; a panic resolves to
//!   [`FleetSupervisor::mark_dead`], which **retires the shard in the
//!   [`StealCoordinator`]** — its inbox drains into the orphan pool
//!   (any live shard adopts the migrations), its pending demands are
//!   cancelled, and the fleet-done check no longer waits on it.
//! * Deaths are recorded as structured [`ShardDied`] values (shard
//!   index + stringified panic payload) that surface in
//!   [`FleetRun::deaths`](crate::shard::FleetRun) instead of a
//!   propagated panic, so drivers can run recovery (re-place the dead
//!   shard's offline work from its newest `JobStore` checkpoints,
//!   report its online requests as failed for client retry — see
//!   `crate::batch::run_jobs_with_recovery`).
//!
//! ## Heartbeats
//!
//! Liveness detection rides on the load board: every engine iteration
//! bumps the shard's [`ShardLoads`] publish sequence number, and the
//! idle-wait loop bumps it too ([`ShardLoads::beat`]), so a healthy
//! shard's sequence always advances between supervisor samples. A
//! still-`RUNNING` shard whose sequence number froze is *stalled* —
//! [`FleetSupervisor::sample_stalled`] reports it. Panics are caught
//! directly (above), so in-process the heartbeat is a watchdog for
//! hangs, not the primary death signal; a multi-process deployment
//! would promote it to one.

use super::steal::StealCoordinator;
use super::ShardLoads;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Arc, Mutex};

/// A shard worker terminated by panic instead of running to
/// completion: the structured death record drivers receive in place of
/// a propagated panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardDied {
    pub shard: usize,
    /// The panic payload, stringified (`<non-string panic payload>`
    /// when the payload was neither `String` nor `&str`).
    pub payload: String,
}

impl ShardDied {
    /// Engine iteration at death, when the payload carries one. Injected
    /// kills panic with `"...: shard S at iteration N"` (see
    /// [`crate::util::fault::INJECTED_PANIC_MARKER`]); post-mortem
    /// tooling matches this against the final `ShardDeath` flight-record
    /// event. Organic panics without the suffix return `None`.
    pub fn iteration(&self) -> Option<u64> {
        let (_, tail) = self.payload.rsplit_once("at iteration ")?;
        tail.split_whitespace().next()?.parse().ok()
    }
}

impl std::fmt::Display for ShardDied {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shard {} died: {}", self.shard, self.payload)
    }
}

impl std::error::Error for ShardDied {}

const RUNNING: u8 = 0;
const DONE: u8 = 1;
const DEAD: u8 = 2;

/// Shared supervision state for one fleet run: per-shard lifecycle
/// flags (running / done / dead), the death log, and the last-seen
/// heartbeat sequence numbers. All methods are `&self` and lock-free
/// on the lifecycle path — workers touch it twice (once at startup via
/// construction, once at exit), never per iteration.
pub struct FleetSupervisor {
    states: Vec<AtomicU8>,
    deaths: Mutex<Vec<ShardDied>>,
    loads: Arc<ShardLoads>,
    steal: Option<Arc<StealCoordinator>>,
    last_seqs: Mutex<Vec<u64>>,
}

impl FleetSupervisor {
    /// A supervisor over the shards of `loads`, retiring dead shards in
    /// `steal` (when the fleet runs the steal protocol).
    pub fn new(loads: Arc<ShardLoads>, steal: Option<Arc<StealCoordinator>>) -> Self {
        let n = loads.n_shards();
        Self {
            states: (0..n).map(|_| AtomicU8::new(RUNNING)).collect(),
            deaths: Mutex::new(Vec::new()),
            loads,
            steal,
            // u64::MAX: the first heartbeat sample never reports a
            // stall (any real sequence value counts as an advance)
            last_seqs: Mutex::new(vec![u64::MAX; n]),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.states.len()
    }

    /// `shard`'s worker ran to completion.
    pub fn mark_done(&self, shard: usize) {
        let _ = self.states[shard].compare_exchange(
            RUNNING,
            DONE,
            Ordering::AcqRel,
            Ordering::Acquire,
        );
    }

    /// `shard`'s worker panicked. Idempotent (first caller wins);
    /// retires the shard in the steal coordinator — stranded inbox
    /// deliveries drain to the orphan pool, pending demands are
    /// cancelled, fleet termination stops waiting on it — and records
    /// the death. Returns true iff this call performed the transition.
    pub fn mark_dead(&self, shard: usize, payload: String) -> bool {
        if self.states[shard]
            .compare_exchange(RUNNING, DEAD, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return false;
        }
        if let Some(st) = &self.steal {
            st.retire(shard);
        }
        self.deaths.lock().unwrap().push(ShardDied { shard, payload });
        true
    }

    pub fn is_dead(&self, shard: usize) -> bool {
        self.states[shard].load(Ordering::Acquire) == DEAD
    }

    pub fn dead_count(&self) -> usize {
        self.states
            .iter()
            .filter(|s| s.load(Ordering::Acquire) == DEAD)
            .count()
    }

    /// All recorded deaths, in the order they were observed.
    pub fn deaths(&self) -> Vec<ShardDied> {
        self.deaths.lock().unwrap().clone()
    }

    /// True once every shard has exited (done or dead) — the stall
    /// monitor's termination condition.
    pub fn all_settled(&self) -> bool {
        self.states
            .iter()
            .all(|s| s.load(Ordering::Acquire) != RUNNING)
    }

    /// Take one heartbeat sample: returns the shards still marked
    /// running whose [`ShardLoads`] publish sequence did not advance
    /// since the previous sample. The first sample never reports a
    /// stall (there is no previous observation to compare against).
    pub fn sample_stalled(&self) -> Vec<usize> {
        let mut last = self.last_seqs.lock().unwrap();
        let mut stalled = Vec::new();
        for shard in 0..self.states.len() {
            let seq = self.loads.publish_seq(shard);
            let moved = seq != last[shard];
            last[shard] = seq;
            if !moved && self.states[shard].load(Ordering::Acquire) == RUNNING {
                stalled.push(shard);
            }
        }
        stalled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shard::StealConfig;

    fn supervisor(n: usize) -> (FleetSupervisor, Arc<ShardLoads>) {
        let loads = Arc::new(ShardLoads::new(n, 1000));
        (FleetSupervisor::new(loads.clone(), None), loads)
    }

    #[test]
    fn lifecycle_and_death_log() {
        let (sup, _loads) = supervisor(3);
        assert_eq!(sup.n_shards(), 3);
        assert!(!sup.all_settled());
        sup.mark_done(0);
        assert!(sup.mark_dead(1, "boom".into()));
        assert!(!sup.mark_dead(1, "again".into()), "death is idempotent");
        assert!(!sup.mark_dead(0, "late".into()), "done shards cannot die");
        assert!(sup.is_dead(1));
        assert!(!sup.is_dead(0));
        assert_eq!(sup.dead_count(), 1);
        assert!(!sup.all_settled(), "shard 2 still running");
        sup.mark_done(2);
        assert!(sup.all_settled());
        let deaths = sup.deaths();
        assert_eq!(deaths.len(), 1);
        assert_eq!(deaths[0], ShardDied { shard: 1, payload: "boom".into() });
        assert_eq!(deaths[0].to_string(), "shard 1 died: boom");
    }

    #[test]
    fn iteration_parses_injected_kill_payloads() {
        let d = ShardDied {
            shard: 2,
            payload: "fault-injected kill: shard 2 at iteration 417".into(),
        };
        assert_eq!(d.iteration(), Some(417));
        let organic = ShardDied { shard: 0, payload: "index out of bounds".into() };
        assert_eq!(organic.iteration(), None);
    }

    #[test]
    fn mark_dead_retires_the_shard_in_the_coordinator() {
        let loads = Arc::new(ShardLoads::new(2, 1000));
        let st = Arc::new(StealCoordinator::new(StealConfig::default(), loads.clone()));
        let sup = FleetSupervisor::new(loads, Some(st.clone()));
        // the dead shard's idle flag flips via retire, so a lone
        // survivor entering idle can finish the fleet
        sup.mark_dead(1, "kill".into());
        st.enter_idle(0);
        assert!(st.finished(), "fleet termination must not wait on a corpse");
    }

    #[test]
    fn heartbeat_sampling_reports_frozen_running_shards() {
        let (sup, loads) = supervisor(2);
        assert!(sup.sample_stalled().is_empty(), "first sample never stalls");
        loads.beat(0); // shard 0 heartbeats, shard 1 does not
        assert_eq!(sup.sample_stalled(), vec![1]);
        // settled shards are exempt even when frozen
        sup.mark_done(1);
        loads.beat(0);
        assert!(sup.sample_stalled().is_empty());
        // a publish counts as a heartbeat too
        loads.publish(0, 1, 0, 0, 0, 0);
        sup.mark_dead(0, "x".into()); // dead shards are exempt as well
        assert!(sup.sample_stalled().is_empty());
    }
}
