//! SLO-aware admission control for the live front door (ISSUE 7; the
//! EconoServe/HyGen framing of co-serving: admission is where online
//! SLOs are defended, and offline work gets *enforced* latency
//! constraints, not mere tolerance).
//!
//! Three gates, evaluated in order, cheapest first:
//!
//! 1. **Drain gate** — a draining server accepts nothing (structured
//!    `503 "draining"`).
//! 2. **Queue-depth + occupancy gates** — fed by the live
//!    [`FleetOccupancy`] aggregate of the shards' published loads.
//!    Offline thresholds sit *below* online ones, and offline is
//!    additionally shed while online queueing pressure exists at all —
//!    so under overload the offline class always sheds first, before
//!    online TTFT degrades (the paper's harvest-must-never-hurt
//!    invariant, applied at the door).
//! 3. **Per-class token buckets** — rate-limit what the queues cannot
//!    see yet: a burst arriving between engine publishes.
//!
//! Every shed carries a machine-readable retry hint
//! ([`Decision::Shed`], surfaced as `429` + `Retry-After`); nothing is
//! silently dropped.
//!
//! Batch jobs additionally pass a **deadline-feasibility** check at
//! submit ([`AdmissionController::admit_job`]): the estimated fleet
//! finish time under current load ([`estimate_finish_us`]) is compared
//! with the job's deadline slack — infeasible-now-but-close jobs are
//! *down-tiered* to best-effort (deadline stripped) rather than queued
//! to die, hopeless ones are rejected with a retry hint, and every
//! verdict is recorded per tenant. The estimator is deliberately
//! **monotone**: adding load (more resident KV, deeper queues, more
//! online share) never decreases the finish estimate, so added load can
//! never flip a job from infeasible to feasible
//! (`tests/admission_props.rs` holds this as a property).

use crate::shard::FleetOccupancy;
use crate::TimeUs;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Nominal per-shard decode service rate (tokens/s) used by the
/// feasibility estimator when the caller provides no measured rate. The
/// modelled A100/7B testbed sustains roughly this in steady state.
pub const NOMINAL_TOK_PER_S: f64 = 5000.0;

/// Admission policy knobs. Defaults defend a small (2-4 shard) simulated
/// fleet; `conserve serve --set admission.<knob>=v` overrides.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Online request token bucket: sustained rate (req/s) and burst.
    pub online_rate: f64,
    pub online_burst: f64,
    /// Offline/batch-member token bucket.
    pub offline_rate: f64,
    pub offline_burst: f64,
    /// Shed online work when the fleet's waiting-online depth (waiting
    /// minus offline backlog) reaches this.
    pub max_waiting_online: u64,
    /// Shed offline work when the fleet's offline backlog reaches this.
    /// Sits far below the online gate: offline sheds first.
    pub max_waiting_offline: u64,
    /// Shed online work above this fleet KV occupancy fraction.
    pub online_occupancy_max: f64,
    /// Shed offline work above this fleet KV occupancy fraction
    /// (< `online_occupancy_max`: offline sheds first).
    pub offline_occupancy_max: f64,
    /// Shed offline work while fleet online queueing pressure is at or
    /// above this many waiting online requests (harvest never queues
    /// behind a degrading online class).
    pub offline_online_pressure: u64,
    /// Per-shard decode service rate (tokens/s) for the feasibility
    /// estimator.
    pub svc_tok_per_s: f64,
    /// Harvest-capacity safety margin in (0, 1]: the estimator assumes
    /// only this fraction of the idle capacity is actually harvestable.
    pub feasibility_margin: f64,
    /// Work estimate (tokens) per already-queued offline request, for
    /// backlog ahead of a new job.
    pub est_tokens_per_offline: u64,
    /// Queue-delay estimate (µs) per waiting online request (they run
    /// first and push offline service out).
    pub online_queue_delay_us: u64,
    /// A job whose estimated finish exceeds its slack but stays within
    /// `slack * reject_over` is down-tiered to best-effort instead of
    /// rejected; beyond that it is rejected outright.
    pub reject_over: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        Self {
            online_rate: 50.0,
            online_burst: 100.0,
            offline_rate: 25.0,
            offline_burst: 50.0,
            max_waiting_online: 64,
            max_waiting_offline: 32,
            online_occupancy_max: 0.97,
            offline_occupancy_max: 0.85,
            offline_online_pressure: 16,
            svc_tok_per_s: NOMINAL_TOK_PER_S,
            feasibility_margin: 0.7,
            est_tokens_per_offline: 1024,
            online_queue_delay_us: 50_000,
            reject_over: 4.0,
        }
    }
}

impl AdmissionConfig {
    /// A gate that admits everything (the `--admission off` baseline of
    /// the bench: overload then lands on the queues unchecked).
    pub fn admit_all() -> Self {
        Self {
            online_rate: f64::INFINITY,
            online_burst: f64::INFINITY,
            offline_rate: f64::INFINITY,
            offline_burst: f64::INFINITY,
            max_waiting_online: u64::MAX,
            max_waiting_offline: u64::MAX,
            online_occupancy_max: f64::INFINITY,
            offline_occupancy_max: f64::INFINITY,
            offline_online_pressure: u64::MAX,
            reject_over: f64::INFINITY,
            ..Self::default()
        }
    }
}

/// Why a request was shed (the `reason` field of the structured 429).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Token bucket empty: sustained rate exceeded.
    RateLimit,
    /// Fleet waiting-queue depth at the class's gate.
    QueueFull,
    /// Fleet KV occupancy above the class's gate.
    Occupancy,
    /// Server is draining: retry against another replica.
    Draining,
}

impl ShedReason {
    pub fn as_str(self) -> &'static str {
        match self {
            ShedReason::RateLimit => "rate_limit",
            ShedReason::QueueFull => "queue_full",
            ShedReason::Occupancy => "occupancy",
            ShedReason::Draining => "draining",
        }
    }
}

/// Per-request admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Admit,
    /// Shed with a structured retry hint (always >= 1 ms — a 0 would
    /// read as "retry immediately" and re-herd the burst).
    Shed {
        retry_after_ms: u64,
        reason: ShedReason,
    },
}

impl Decision {
    pub fn admitted(&self) -> bool {
        matches!(self, Decision::Admit)
    }
}

/// Job-level admission verdict (deadline feasibility at submit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum JobVerdict {
    /// Deadline (or no deadline) is feasible under current load.
    Accept { est_finish_ms: u64 },
    /// Deadline is infeasible but the job is worth running best-effort:
    /// deadline stripped, urgency zeroed, tier demoted.
    DownTier { est_finish_ms: u64 },
    /// Hopeless under current load (or the door is closed): not queued.
    Reject {
        retry_after_ms: u64,
        reason: ShedReason,
    },
}

/// The slice of fleet state the estimator reads — a plain value type so
/// the monotonicity property can enumerate it directly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetView {
    pub n_shards: u64,
    /// Per-shard KV capacity in blocks.
    pub capacity_blocks: u64,
    /// Σ online-reserved KV blocks.
    pub online_blocks: u64,
    /// Σ waiting online requests.
    pub waiting_online: u64,
    /// Σ queued offline requests.
    pub offline_waiting: u64,
    /// Mean live offline token budget across shards, permille of the
    /// static `max_batch_tokens` (published by harvest controllers via
    /// [`crate::shard::ShardLoads::publish_budget`]; 1000 when no
    /// controller is tightening).
    pub budget_permille: u64,
}

impl From<FleetOccupancy> for FleetView {
    fn from(o: FleetOccupancy) -> Self {
        FleetView {
            n_shards: o.n_shards as u64,
            capacity_blocks: o.capacity_blocks,
            online_blocks: o.online_blocks,
            waiting_online: o.waiting.saturating_sub(o.offline_waiting),
            offline_waiting: o.offline_waiting,
            budget_permille: o.budget_permille,
        }
    }
}

/// Estimated time (µs from now) for a new offline job of `job_tokens`
/// total work to finish under the current fleet load.
///
/// Model: each shard harvests `svc * margin * (1 - online_frac)` tokens
/// per second, where `online_frac` is the online-reserved share of fleet
/// KV (capped at 0.95 so harvest never estimates exactly zero — the
/// slack-harvesting floor). The job waits behind the current offline
/// backlog and behind online queueing delay.
///
/// The harvest rate is further scaled by the *live published budget*
/// (`budget_permille / 1000`, floored at 5 %): a fleet whose harvest
/// controllers have tightened to a fraction of the static
/// `max_batch_tokens` can only finish offline work at that fraction of
/// the nominal rate, and admission must not accept jobs the tightened
/// harvester can no longer finish. The floor keeps the estimate finite
/// (mirroring the 0.95 occupancy cap) and 1000 — the no-controller
/// default — reproduces the pre-harvest estimate exactly.
///
/// **Monotone by construction** in every load component: increasing
/// `online_blocks`, `waiting_online` or `offline_waiting` never
/// decreases the estimate, and *decreasing* `budget_permille` never
/// decreases it either (property-tested). Conservative, not exact —
/// the gate errs toward down-tiering.
pub fn estimate_finish_us(view: &FleetView, cfg: &AdmissionConfig, job_tokens: u64) -> u64 {
    let shards = view.n_shards.max(1) as f64;
    let cap = (view.n_shards.max(1) * view.capacity_blocks.max(1)) as f64;
    let online_frac = (view.online_blocks as f64 / cap).min(0.95);
    let budget_frac = view.budget_permille.clamp(50, 1000) as f64 / 1000.0;
    let harvest =
        shards * cfg.svc_tok_per_s.max(1.0) * cfg.feasibility_margin.clamp(0.01, 1.0)
            * (1.0 - online_frac)
            * budget_frac;
    let backlog_tokens =
        view.offline_waiting.saturating_mul(cfg.est_tokens_per_offline) as f64;
    let queue_delay_us =
        view.waiting_online.saturating_mul(cfg.online_queue_delay_us) as f64;
    let decode_us = (backlog_tokens + job_tokens as f64) / harvest * 1e6;
    let total = queue_delay_us + decode_us;
    if total >= u64::MAX as f64 {
        u64::MAX
    } else {
        total as u64
    }
}

/// Is a job of `job_tokens` total work feasible within `slack_us` of
/// deadline headroom under the current load?
pub fn deadline_feasible(
    view: &FleetView,
    cfg: &AdmissionConfig,
    job_tokens: u64,
    slack_us: u64,
) -> bool {
    estimate_finish_us(view, cfg, job_tokens) <= slack_us
}

/// Classic token bucket over a microsecond clock.
#[derive(Debug)]
struct TokenBucket {
    rate_per_us: f64,
    burst: f64,
    tokens: f64,
    last: TimeUs,
}

impl TokenBucket {
    fn new(rate_per_s: f64, burst: f64) -> Self {
        Self {
            rate_per_us: rate_per_s / 1e6,
            burst,
            tokens: burst,
            last: 0,
        }
    }

    /// Take one token, or report how long (µs) until one accrues.
    fn try_take(&mut self, now: TimeUs) -> Result<(), u64> {
        if self.burst.is_infinite() {
            return Ok(());
        }
        // clock-regression guard: never refill backwards
        if now > self.last {
            self.tokens =
                (self.tokens + (now - self.last) as f64 * self.rate_per_us).min(self.burst);
            self.last = now;
        }
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            let deficit = 1.0 - self.tokens;
            let eta = if self.rate_per_us > 0.0 {
                (deficit / self.rate_per_us) as u64
            } else {
                u64::MAX / 2
            };
            Err(eta.max(1))
        }
    }
}

/// Per-tenant admission ledger (job verdicts recorded at submit).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantAdmissions {
    pub accepted: u64,
    pub downtiered: u64,
    pub rejected: u64,
}

/// Counter snapshot ([`AdmissionController::counters`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionCounters {
    pub admitted_online: u64,
    pub admitted_offline: u64,
    pub shed_online: u64,
    pub shed_offline: u64,
    pub jobs_accepted: u64,
    pub jobs_downtiered: u64,
    pub jobs_rejected: u64,
}

/// The front door's admission gate. Thread-safe: per-class buckets
/// behind one short-critical-section mutex, everything else atomics.
pub struct AdmissionController {
    cfg: AdmissionConfig,
    buckets: Mutex<[TokenBucket; 2]>, // [online, offline]
    draining: AtomicBool,
    admitted_online: AtomicU64,
    admitted_offline: AtomicU64,
    shed_online: AtomicU64,
    shed_offline: AtomicU64,
    jobs_accepted: AtomicU64,
    jobs_downtiered: AtomicU64,
    jobs_rejected: AtomicU64,
    tenant_log: Mutex<BTreeMap<u32, TenantAdmissions>>,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        let buckets = Mutex::new([
            TokenBucket::new(cfg.online_rate, cfg.online_burst),
            TokenBucket::new(cfg.offline_rate, cfg.offline_burst),
        ]);
        Self {
            cfg,
            buckets,
            draining: AtomicBool::new(false),
            admitted_online: AtomicU64::new(0),
            admitted_offline: AtomicU64::new(0),
            shed_online: AtomicU64::new(0),
            shed_offline: AtomicU64::new(0),
            jobs_accepted: AtomicU64::new(0),
            jobs_downtiered: AtomicU64::new(0),
            jobs_rejected: AtomicU64::new(0),
            tenant_log: Mutex::new(BTreeMap::new()),
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Close the door: every subsequent decision sheds with
    /// [`ShedReason::Draining`]. One-way.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }

    fn shed(&self, online: bool, retry_after_ms: u64, reason: ShedReason) -> Decision {
        if online {
            self.shed_online.fetch_add(1, Ordering::Relaxed);
        } else {
            self.shed_offline.fetch_add(1, Ordering::Relaxed);
        }
        Decision::Shed {
            retry_after_ms: retry_after_ms.max(1),
            reason,
        }
    }

    /// Gate one online request.
    pub fn admit_online(&self, view: &FleetView, now: TimeUs) -> Decision {
        if self.is_draining() {
            return self.shed(true, 1_000, ShedReason::Draining);
        }
        if view.waiting_online >= self.cfg.max_waiting_online {
            // ~time to serve half the backlog ahead
            let ms = (view.waiting_online * self.cfg.online_queue_delay_us / 2_000).max(1);
            return self.shed(true, ms, ShedReason::QueueFull);
        }
        let cap = (view.n_shards.max(1) * view.capacity_blocks.max(1)) as f64;
        if view.online_blocks as f64 / cap > self.cfg.online_occupancy_max {
            return self.shed(true, 250, ShedReason::Occupancy);
        }
        match self.buckets.lock().unwrap()[0].try_take(now) {
            Ok(()) => {
                self.admitted_online.fetch_add(1, Ordering::Relaxed);
                Decision::Admit
            }
            Err(eta_us) => self.shed(true, eta_us.div_ceil(1_000), ShedReason::RateLimit),
        }
    }

    /// Gate one offline request (or one batch member). Sheds strictly
    /// earlier than [`admit_online`](Self::admit_online): lower queue +
    /// occupancy thresholds, plus an online-pressure gate.
    pub fn admit_offline(&self, view: &FleetView, now: TimeUs) -> Decision {
        if self.is_draining() {
            return self.shed(false, 1_000, ShedReason::Draining);
        }
        if view.offline_waiting >= self.cfg.max_waiting_offline
            || view.waiting_online >= self.cfg.offline_online_pressure
        {
            let ms = ((view.offline_waiting + view.waiting_online) * 20).max(1);
            return self.shed(false, ms, ShedReason::QueueFull);
        }
        let cap = (view.n_shards.max(1) * view.capacity_blocks.max(1)) as f64;
        let resident_frac = view.online_blocks as f64 / cap;
        if resident_frac > self.cfg.offline_occupancy_max {
            return self.shed(false, 500, ShedReason::Occupancy);
        }
        match self.buckets.lock().unwrap()[1].try_take(now) {
            Ok(()) => {
                self.admitted_offline.fetch_add(1, Ordering::Relaxed);
                Decision::Admit
            }
            Err(eta_us) => self.shed(false, eta_us.div_ceil(1_000), ShedReason::RateLimit),
        }
    }

    /// Deadline-feasibility verdict for a whole job of `job_tokens`
    /// total work with `deadline` (µs timestamp, 0 = best-effort) at
    /// `now`. Recorded per tenant.
    pub fn admit_job(
        &self,
        view: &FleetView,
        tenant: u32,
        job_tokens: u64,
        deadline: TimeUs,
        now: TimeUs,
    ) -> JobVerdict {
        let v = self.job_verdict(view, job_tokens, deadline, now);
        let mut log = self.tenant_log.lock().unwrap();
        let t = log.entry(tenant).or_default();
        match v {
            JobVerdict::Accept { .. } => {
                t.accepted += 1;
                self.jobs_accepted.fetch_add(1, Ordering::Relaxed);
            }
            JobVerdict::DownTier { .. } => {
                t.downtiered += 1;
                self.jobs_downtiered.fetch_add(1, Ordering::Relaxed);
            }
            JobVerdict::Reject { .. } => {
                t.rejected += 1;
                self.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            }
        }
        v
    }

    fn job_verdict(
        &self,
        view: &FleetView,
        job_tokens: u64,
        deadline: TimeUs,
        now: TimeUs,
    ) -> JobVerdict {
        if self.is_draining() {
            return JobVerdict::Reject {
                retry_after_ms: 1_000,
                reason: ShedReason::Draining,
            };
        }
        if view.offline_waiting >= self.cfg.max_waiting_offline {
            let ms = (view.offline_waiting * 20).max(1);
            return JobVerdict::Reject {
                retry_after_ms: ms,
                reason: ShedReason::QueueFull,
            };
        }
        let est = estimate_finish_us(view, &self.cfg, job_tokens);
        let est_ms = est.div_ceil(1_000);
        if deadline == 0 {
            // best-effort jobs carry no promise to break
            return JobVerdict::Accept { est_finish_ms: est_ms };
        }
        let slack = deadline.saturating_sub(now);
        if est <= slack {
            JobVerdict::Accept { est_finish_ms: est_ms }
        } else if (est as f64) <= slack as f64 * self.cfg.reject_over.max(1.0) {
            JobVerdict::DownTier { est_finish_ms: est_ms }
        } else {
            // hopeless: suggest retrying once roughly half the estimated
            // backlog has drained
            JobVerdict::Reject {
                retry_after_ms: (est_ms / 2).max(1),
                reason: ShedReason::QueueFull,
            }
        }
    }

    /// Snapshot of the admission counters (merged into the serve
    /// report).
    pub fn counters(&self) -> AdmissionCounters {
        AdmissionCounters {
            admitted_online: self.admitted_online.load(Ordering::Relaxed),
            admitted_offline: self.admitted_offline.load(Ordering::Relaxed),
            shed_online: self.shed_online.load(Ordering::Relaxed),
            shed_offline: self.shed_offline.load(Ordering::Relaxed),
            jobs_accepted: self.jobs_accepted.load(Ordering::Relaxed),
            jobs_downtiered: self.jobs_downtiered.load(Ordering::Relaxed),
            jobs_rejected: self.jobs_rejected.load(Ordering::Relaxed),
        }
    }

    /// Per-tenant job-verdict ledger (ascending tenant id).
    pub fn tenant_ledger(&self) -> Vec<(u32, TenantAdmissions)> {
        self.tenant_log
            .lock()
            .unwrap()
            .iter()
            .map(|(&t, &a)| (t, a))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quiet_view() -> FleetView {
        FleetView {
            n_shards: 2,
            capacity_blocks: 1000,
            online_blocks: 0,
            waiting_online: 0,
            offline_waiting: 0,
            budget_permille: 1000,
        }
    }

    #[test]
    fn token_bucket_rate_limits_and_reports_eta() {
        let mut b = TokenBucket::new(10.0, 2.0); // 10/s, burst 2
        assert!(b.try_take(0).is_ok());
        assert!(b.try_take(0).is_ok());
        let eta = b.try_take(0).unwrap_err();
        // one token accrues in 100ms
        assert!((90_000..=110_000).contains(&eta), "eta={eta}");
        assert!(b.try_take(eta).is_ok());
        // clock regression: no refill, no panic
        let mut b2 = TokenBucket::new(10.0, 1.0);
        assert!(b2.try_take(500_000).is_ok());
        assert!(b2.try_take(400_000).is_err());
    }

    #[test]
    fn offline_sheds_before_online() {
        let ctl = AdmissionController::new(AdmissionConfig::default());
        // online queueing pressure alone sheds offline but not online
        let view = FleetView {
            waiting_online: 20, // >= offline_online_pressure, < max_waiting_online
            ..quiet_view()
        };
        assert!(ctl.admit_online(&view, 0).admitted());
        let d = ctl.admit_offline(&view, 0);
        assert!(matches!(
            d,
            Decision::Shed {
                reason: ShedReason::QueueFull,
                ..
            }
        ));
        // occupancy band between the two gates: offline sheds only
        let view = FleetView {
            online_blocks: 1800, // 0.9 of 2000: > 0.85, < 0.97
            ..quiet_view()
        };
        assert!(ctl.admit_online(&view, 1).admitted());
        assert!(!ctl.admit_offline(&view, 1).admitted());
        let c = ctl.counters();
        assert_eq!(c.shed_online, 0);
        assert_eq!(c.shed_offline, 2);
        assert_eq!(c.admitted_online, 2);
    }

    #[test]
    fn every_shed_carries_positive_retry_hint() {
        let ctl = AdmissionController::new(AdmissionConfig {
            online_rate: 0.001,
            online_burst: 1.0,
            ..Default::default()
        });
        let view = quiet_view();
        assert!(ctl.admit_online(&view, 0).admitted());
        for now in [0, 1, 2] {
            match ctl.admit_online(&view, now) {
                Decision::Shed { retry_after_ms, .. } => assert!(retry_after_ms >= 1),
                d => panic!("expected shed, got {d:?}"),
            }
        }
    }

    #[test]
    fn draining_sheds_everything() {
        let ctl = AdmissionController::new(AdmissionConfig::default());
        ctl.begin_drain();
        let view = quiet_view();
        assert!(matches!(
            ctl.admit_online(&view, 0),
            Decision::Shed {
                reason: ShedReason::Draining,
                ..
            }
        ));
        assert!(matches!(
            ctl.admit_offline(&view, 0),
            Decision::Shed {
                reason: ShedReason::Draining,
                ..
            }
        ));
        assert!(matches!(
            ctl.admit_job(&view, 0, 100, 0, 0),
            JobVerdict::Reject {
                reason: ShedReason::Draining,
                ..
            }
        ));
    }

    #[test]
    fn job_verdicts_accept_downtier_reject() {
        let cfg = AdmissionConfig::default();
        let ctl = AdmissionController::new(cfg.clone());
        let view = quiet_view();
        let now = 1_000_000;
        // generous deadline: accept
        let est = estimate_finish_us(&view, &cfg, 10_000);
        match ctl.admit_job(&view, 1, 10_000, now + est * 2, now) {
            JobVerdict::Accept { .. } => {}
            v => panic!("expected accept, got {v:?}"),
        }
        // slack below the estimate but within reject_over: down-tier
        match ctl.admit_job(&view, 1, 10_000, now + est / 2, now) {
            JobVerdict::DownTier { .. } => {}
            v => panic!("expected downtier, got {v:?}"),
        }
        // hopeless slack: reject with a positive hint
        match ctl.admit_job(&view, 2, 10_000, now + 1, now) {
            JobVerdict::Reject { retry_after_ms, .. } => assert!(retry_after_ms >= 1),
            v => panic!("expected reject, got {v:?}"),
        }
        // no deadline: always accept (best-effort carries no promise)
        match ctl.admit_job(&view, 3, 1_000_000_000, 0, now) {
            JobVerdict::Accept { .. } => {}
            v => panic!("expected accept, got {v:?}"),
        }
        let ledger = ctl.tenant_ledger();
        assert_eq!(
            ledger,
            vec![
                (1, TenantAdmissions { accepted: 1, downtiered: 1, rejected: 0 }),
                (2, TenantAdmissions { accepted: 0, downtiered: 0, rejected: 1 }),
                (3, TenantAdmissions { accepted: 1, downtiered: 0, rejected: 0 }),
            ]
        );
        let c = ctl.counters();
        assert_eq!((c.jobs_accepted, c.jobs_downtiered, c.jobs_rejected), (2, 1, 1));
    }

    #[test]
    fn admit_all_never_sheds() {
        let ctl = AdmissionController::new(AdmissionConfig::admit_all());
        let view = FleetView {
            n_shards: 1,
            capacity_blocks: 10,
            online_blocks: 10,
            waiting_online: 1_000_000,
            offline_waiting: 1_000_000,
            budget_permille: 1000,
        };
        for now in 0..100 {
            assert!(ctl.admit_online(&view, now).admitted());
            assert!(ctl.admit_offline(&view, now).admitted());
        }
    }

    #[test]
    fn estimator_monotone_spot_checks() {
        let cfg = AdmissionConfig::default();
        let base = quiet_view();
        let e0 = estimate_finish_us(&base, &cfg, 10_000);
        for bumped in [
            FleetView { online_blocks: 500, ..base },
            FleetView { waiting_online: 10, ..base },
            FleetView { offline_waiting: 10, ..base },
        ] {
            assert!(estimate_finish_us(&bumped, &cfg, 10_000) >= e0);
        }
        // more work never finishes sooner
        assert!(estimate_finish_us(&base, &cfg, 20_000) >= e0);
    }
}
