//! Live counter mirror + Prometheus text-format rendering for the
//! `GET /metrics` endpoint.
//!
//! The engine's [`Recorder`] lives inside the engine thread, so the
//! HTTP server cannot read it directly. Instead each engine publishes a
//! handful of relaxed atomic stores into its [`ShardStats`] cell once
//! per iteration (quantiles every [`QUANTILE_EVERY`] iterations — the
//! histogram read is O(buckets)), and the `/metrics` handler renders
//! the cells without touching any engine state. Per-tenant counters go
//! through a tiny `Mutex<Vec<TenantCounters>>` guarded by a
//! fingerprint, so the lock is only taken when a tenant total actually
//! changed.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::{Recorder, TenantCounters};
use crate::request::Class;

/// Engine iterations between quantile publications.
pub const QUANTILE_EVERY: u64 = 32;

/// One shard's live counters (all monotonically published from the
/// engine's recorder; readers use relaxed loads).
#[derive(Debug, Default)]
pub struct ShardStats {
    pub engine_iters: AtomicU64,
    pub finished_online: AtomicU64,
    pub finished_offline: AtomicU64,
    pub gen_tokens: AtomicU64,
    pub processed_tokens: AtomicU64,
    pub preemptions: AtomicU64,
    pub layer_aborts: AtomicU64,
    pub steals_out: AtomicU64,
    pub steals_in: AtomicU64,
    pub ckpt_flush_records: AtomicU64,
    pub ckpt_blocks: AtomicU64,
    pub cancelled: AtomicU64,
    pub prefix_hits: AtomicU64,
    pub prefill_tokens_skipped: AtomicU64,
    pub harvest_tightens: AtomicU64,
    pub harvest_opens: AtomicU64,
    pub deadline_met: AtomicU64,
    pub deadline_missed: AtomicU64,
    /// Online-class P99s in µs (published every [`QUANTILE_EVERY`]).
    pub p99_ttft_us: AtomicU64,
    pub p99_tpot_us: AtomicU64,
    tenants: Mutex<Vec<TenantCounters>>,
    tenant_fingerprint: AtomicU64,
}

impl ShardStats {
    /// Mirror the cheap counters (≈20 relaxed stores).
    pub fn publish_counters(&self, r: &Recorder) {
        let o = Ordering::Relaxed;
        self.engine_iters.store(r.engine_iters, o);
        self.finished_online.store(r.finished[0], o);
        self.finished_offline.store(r.finished[1], o);
        self.gen_tokens.store(r.gen_token_count(None), o);
        self.processed_tokens.store(r.processed_token_count(None), o);
        self.preemptions.store(r.preemptions, o);
        self.layer_aborts.store(r.layer_aborts, o);
        self.steals_out.store(r.steals_out, o);
        self.steals_in.store(r.steals_in, o);
        self.ckpt_flush_records.store(r.ckpt_flush_records, o);
        self.ckpt_blocks.store(r.ckpt_blocks, o);
        self.cancelled.store(r.cancelled, o);
        self.prefix_hits.store(r.prefix_hits, o);
        self.prefill_tokens_skipped
            .store(r.prefill_tokens_skipped, o);
        self.harvest_tightens.store(r.harvest_tightens, o);
        self.harvest_opens.store(r.harvest_opens, o);
        self.deadline_met.store(r.deadline_met, o);
        self.deadline_missed.store(r.deadline_missed, o);
    }

    /// Mirror the online P99s (O(histogram buckets) — publish rarely).
    pub fn publish_quantiles(&self, r: &Recorder) {
        let o = Ordering::Relaxed;
        self.p99_ttft_us
            .store((r.p99_ttft_ms(Class::Online) * 1_000.0) as u64, o);
        self.p99_tpot_us
            .store((r.p99_tpot_ms(Class::Online) * 1_000.0) as u64, o);
    }

    /// Mirror per-tenant counters if they changed since the last call
    /// (fingerprint check avoids the lock on the common no-change path;
    /// `clone_from` reuses the mirror's capacity, so steady state is
    /// allocation-free).
    pub fn publish_tenants(&self, r: &Recorder) {
        let fp = r
            .tenants
            .iter()
            .fold(r.tenants.len() as u64, |acc, t| {
                acc.wrapping_mul(1_000_003)
                    .wrapping_add(t.finished + t.gen_tokens + t.deadline_met + t.deadline_missed)
            });
        if self.tenant_fingerprint.swap(fp, Ordering::Relaxed) != fp {
            self.tenants.lock().unwrap().clone_from(&r.tenants);
        }
    }

    /// One full publication (counters + quantiles + tenants) — used at
    /// engine shutdown so the final scrape is exact.
    pub fn publish_all(&self, r: &Recorder) {
        self.publish_counters(r);
        self.publish_quantiles(r);
        self.publish_tenants(r);
    }

    pub fn tenants(&self) -> Vec<TenantCounters> {
        self.tenants.lock().unwrap().clone()
    }
}

/// The fleet's live stats: one cell per shard.
#[derive(Debug)]
pub struct MetricsHub {
    shards: Vec<Arc<ShardStats>>,
}

impl MetricsHub {
    pub fn new(n_shards: usize) -> Arc<Self> {
        Arc::new(Self {
            shards: (0..n_shards).map(|_| Arc::new(ShardStats::default())).collect(),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shard(&self, i: usize) -> Arc<ShardStats> {
        self.shards[i].clone()
    }

    pub fn cells(&self) -> &[Arc<ShardStats>] {
        &self.shards
    }

    fn sum(&self, f: impl Fn(&ShardStats) -> &AtomicU64) -> u64 {
        self.shards
            .iter()
            .map(|s| f(s).load(Ordering::Relaxed))
            .sum()
    }

    /// Tenant counters merged across shards, sorted by tenant id.
    pub fn merged_tenants(&self) -> Vec<TenantCounters> {
        let mut out: Vec<TenantCounters> = Vec::new();
        for s in &self.shards {
            for t in s.tenants() {
                match out.iter_mut().find(|c| c.tenant == t.tenant) {
                    Some(c) => {
                        c.finished += t.finished;
                        c.gen_tokens += t.gen_tokens;
                        c.deadline_met += t.deadline_met;
                        c.deadline_missed += t.deadline_missed;
                    }
                    None => out.push(t),
                }
            }
        }
        out.sort_by_key(|t| t.tenant);
        out
    }

    /// Fleet-wide deadline attainment (1.0 when nothing carried one).
    pub fn deadline_attainment(&self) -> f64 {
        let met = self.sum(|s| &s.deadline_met);
        let missed = self.sum(|s| &s.deadline_missed);
        if met + missed == 0 {
            1.0
        } else {
            met as f64 / (met + missed) as f64
        }
    }

    /// Render every engine family into `out` (Prometheus text format,
    /// deterministic family and label order). The HTTP layer appends
    /// its own front-door families after this.
    pub fn render_into(&self, out: &mut String) {
        let per_shard: &[(&str, &str, &str, fn(&ShardStats) -> &AtomicU64)] = &[
            ("conserve_engine_iterations_total", "counter", "Engine scheduling iterations", |s| &s.engine_iters),
            ("conserve_finished_online_total", "counter", "Online requests finished", |s| &s.finished_online),
            ("conserve_finished_offline_total", "counter", "Offline requests finished", |s| &s.finished_offline),
            ("conserve_gen_tokens_total", "counter", "Output tokens generated", |s| &s.gen_tokens),
            ("conserve_processed_tokens_total", "counter", "Tokens processed (prefill + decode)", |s| &s.processed_tokens),
            ("conserve_preemptions_total", "counter", "Requests preempted", |s| &s.preemptions),
            ("conserve_layer_aborts_total", "counter", "Layer-wise safepoint aborts", |s| &s.layer_aborts),
            ("conserve_steals_out_total", "counter", "Requests donated to other shards", |s| &s.steals_out),
            ("conserve_steals_in_total", "counter", "Requests absorbed from other shards", |s| &s.steals_in),
            ("conserve_ckpt_flush_records_total", "counter", "Durable store records flushed", |s| &s.ckpt_flush_records),
            ("conserve_ckpt_blocks_total", "counter", "KV blocks checkpointed to host", |s| &s.ckpt_blocks),
            ("conserve_cancelled_total", "counter", "Requests aborted by client cancellation", |s| &s.cancelled),
            ("conserve_prefix_hits_total", "counter", "Admissions that attached shared prefix blocks", |s| &s.prefix_hits),
            ("conserve_prefill_tokens_skipped_total", "counter", "Prefill tokens skipped via prefix sharing", |s| &s.prefill_tokens_skipped),
            ("conserve_harvest_tightens_total", "counter", "Harvest controller tighten decisions", |s| &s.harvest_tightens),
            ("conserve_harvest_opens_total", "counter", "Harvest controller open decisions", |s| &s.harvest_opens),
            ("conserve_deadline_met_total", "counter", "Deadline-carrying requests finished in time", |s| &s.deadline_met),
            ("conserve_deadline_missed_total", "counter", "Deadline-carrying requests finished late", |s| &s.deadline_missed),
            ("conserve_ttft_p99_ms", "gauge", "Online P99 time-to-first-token (ms)", |s| &s.p99_ttft_us),
            ("conserve_tpot_p99_ms", "gauge", "Online P99 inter-token latency (ms)", |s| &s.p99_tpot_us),
        ];
        for (name, typ, help, get) in per_shard {
            write_family(out, name, help, typ);
            let ms = name.ends_with("_ms");
            for (i, s) in self.shards.iter().enumerate() {
                let raw = get(s).load(Ordering::Relaxed) as f64;
                let v = if ms { raw / 1_000.0 } else { raw };
                write_sample(out, name, &format!("shard=\"{i}\""), v);
            }
        }
        write_family(
            out,
            "conserve_deadline_attainment",
            "Fleet deadline attainment (deadline-carrying requests)",
            "gauge",
        );
        write_sample(out, "conserve_deadline_attainment", "", self.deadline_attainment());
        write_family(
            out,
            "conserve_tenant_deadline_attainment",
            "Per-tenant deadline attainment",
            "gauge",
        );
        for t in self.merged_tenants() {
            write_sample(
                out,
                "conserve_tenant_deadline_attainment",
                &format!("tenant=\"{}\"", t.tenant),
                t.attainment(),
            );
        }
    }
}

/// `# HELP` / `# TYPE` header for one metric family.
pub fn write_family(out: &mut String, name: &str, help: &str, typ: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    out.push_str(help);
    out.push_str("\n# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(typ);
    out.push('\n');
}

/// One sample line; `labels` is the inner label list (no braces) or "".
pub fn write_sample(out: &mut String, name: &str, labels: &str, v: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        out.push_str(labels);
        out.push('}');
    }
    out.push(' ');
    if v.fract() == 0.0 && v.abs() < 1e15 {
        out.push_str(&format!("{}", v as i64));
    } else {
        out.push_str(&format!("{v}"));
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_and_render() {
        let hub = MetricsHub::new(2);
        let mut r = Recorder::new();
        r.engine_iters = 7;
        r.record_first_token(1_000, Class::Online, 120_000);
        r.record_finished(Class::Online);
        r.deadline_met = 3;
        r.deadline_missed = 1;
        r.note_tenant_finished(5, 10, Some(true));
        hub.shard(0).publish_all(&r);
        let mut out = String::new();
        hub.render_into(&mut out);
        assert!(out.contains("conserve_engine_iterations_total{shard=\"0\"} 7"), "{out}");
        assert!(out.contains("conserve_engine_iterations_total{shard=\"1\"} 0"), "{out}");
        assert!(out.contains("conserve_finished_online_total{shard=\"0\"} 1"), "{out}");
        assert!(out.contains("conserve_deadline_attainment 0.75"), "{out}");
        assert!(out.contains("conserve_tenant_deadline_attainment{tenant=\"5\"} 1"), "{out}");
        assert!(out.contains("# TYPE conserve_ttft_p99_ms gauge"), "{out}");
        // quantile published in ms within histogram bucket error
        let line = out
            .lines()
            .find(|l| l.starts_with("conserve_ttft_p99_ms{shard=\"0\"}"))
            .unwrap();
        let v: f64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!((v - 120.0).abs() < 3.0, "{line}");
    }

    #[test]
    fn tenant_mirror_updates_only_on_change() {
        let st = ShardStats::default();
        let mut r = Recorder::new();
        r.note_tenant_finished(1, 4, Some(true));
        st.publish_tenants(&r);
        assert_eq!(st.tenants().len(), 1);
        // unchanged fingerprint: mirror untouched even if we clear it
        st.tenants.lock().unwrap().clear();
        st.publish_tenants(&r);
        assert!(st.tenants().is_empty(), "no change => no re-publish");
        r.note_tenant_finished(2, 1, None);
        st.publish_tenants(&r);
        assert_eq!(st.tenants().len(), 2);
    }

    #[test]
    fn merged_tenants_fold_across_shards() {
        let hub = MetricsHub::new(2);
        let mut a = Recorder::new();
        a.note_tenant_finished(9, 5, Some(true));
        let mut b = Recorder::new();
        b.note_tenant_finished(9, 5, Some(false));
        b.note_tenant_finished(3, 1, None);
        hub.shard(0).publish_all(&a);
        hub.shard(1).publish_all(&b);
        let m = hub.merged_tenants();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].tenant, 3, "sorted by tenant id");
        let t9 = &m[1];
        assert_eq!(t9.finished, 2);
        assert_eq!((t9.deadline_met, t9.deadline_missed), (1, 1));
        assert!((t9.attainment() - 0.5).abs() < 1e-9);
    }
}
