//! Unified tracing / flight recorder: a per-shard, lock-free, fixed-size
//! ring of compact binary trace events, emitted from every decision point
//! the serving stack already has — admission verdicts, queue entry,
//! prefill chunks, decode iterations (estimated vs actual latency),
//! preemption, steal legs, checkpoint flushes, harvest tighten/open,
//! prefix attach/publish/reclaim, and shard death/recovery.
//!
//! Design constraints, in order:
//!
//! 1. **Zero allocation on the hot path.** An event is five `u64` words
//!    written into a preallocated flat `Box<[AtomicU64]>`; emitting is a
//!    reservation `fetch_add` plus five relaxed stores.
//! 2. **Deterministic under the virtual clock.** Timestamps come from the
//!    engine's [`crate::clock::Clock`], so two lockstep sim runs with the
//!    same seed produce byte-identical exported traces
//!    ([`perfetto::export_perfetto`] sorts deterministically and
//!    `util::json` renders `BTreeMap`s in key order).
//! 3. **Readable from another thread while the producer is live.** The
//!    ring is written with atomics, so a supervisor or `/metrics` handler
//!    may snapshot it mid-run without UB. A snapshot raced against the
//!    producer can observe a partially-written *latest* slot (the kind
//!    byte is validated and junk slots are skipped); snapshots taken
//!    after the engine thread joined are exact.
//!
//! Each ring holds the last `cap` events per shard; older events are
//! overwritten (the drop count is `total() - cap`). Post-mortem dumps
//! ([`flight_dump`]) write the surviving tail as JSONL for offline
//! triage; [`perfetto::export_perfetto`] renders the whole fleet as a
//! Chrome/Perfetto trace-event array; [`prometheus`] carries the live
//! counter mirror behind `GET /metrics`.

pub mod perfetto;
pub mod prometheus;

use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::json::{num, obj, Json};
use crate::TimeUs;

/// Default per-shard ring capacity (events). At 40 bytes/event this is
/// ~2.5 MiB per shard — hours of decode iterations, minutes of
/// everything-on tracing.
pub const DEFAULT_RING_EVENTS: usize = 65_536;

/// Flight-recorder dumps keep at most this many trailing events per
/// shard (a dump is for triage, not archival).
pub const DEFAULT_DUMP_LAST: usize = 4_096;

const WORDS_PER_EVENT: usize = 5;

/// Every event kind the stack emits. The discriminant is the on-ring
/// byte — append-only; never renumber (flight dumps on disk carry the
/// *name*, the ring carries the byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// Front door admitted an online request (`sid`; `a` = prompt len).
    AdmitOnline = 0,
    /// Front door shed an online request (`a` = shed-reason code,
    /// `b` = Retry-After hint ms).
    ShedOnline = 1,
    /// Batch submit accepted at full tier (`a` = estimated finish ms).
    JobAccept = 2,
    /// Batch submit admitted at degraded tier (`a` = est finish ms).
    JobDownTier = 3,
    /// Batch submit rejected (`a` = reason code, `b` = Retry-After ms).
    JobReject = 4,
    /// Request entered a shard's scheduler queue (`a` = class 0/1,
    /// `b` = prompt len).
    QueueEnter = 5,
    /// Prefill chunk scheduled for `sid` (`a` = chunk tokens,
    /// `b` = context length before the chunk).
    PrefillChunk = 6,
    /// One engine iteration (`a` = prefill_tokens<<32 | decode_seqs,
    /// `b` = estimated_us<<32 | actual_us).
    Iteration = 7,
    /// Request preempted (`a`: 0 = discarded, 1 = evicted-to-host,
    /// 2 = swapped-out).
    Preempt = 8,
    /// Layer-wise safepoint abort of an in-flight iteration.
    LayerAbort = 9,
    /// This shard posted a steal demand (`a` = chosen donor shard).
    StealDemand = 10,
    /// Request `sid` donated to another shard (`a` = thief shard,
    /// `b` = checkpointed tokens travelling with it).
    StealDonate = 11,
    /// Request `sid` absorbed from another shard (`a` = origin shard,
    /// `b` = checkpointed tokens imported).
    StealAbsorb = 12,
    /// Durable store flush wrote `a` records (`b` = flush interval id).
    CkptFlush = 13,
    /// Harvest controller tightened the offline budget
    /// (`a` = audit-record id, `b` = new budget permille).
    HarvestTighten = 14,
    /// Harvest controller opened the offline budget
    /// (`a` = audit-record id, `b` = new budget permille).
    HarvestOpen = 15,
    /// Admission attached shared prefix blocks this iteration
    /// (`a` = requests that hit, `b` = prefill tokens skipped).
    PrefixAttach = 16,
    /// Commit published `a` blocks of `sid`'s prefix into the share
    /// index.
    PrefixPublish = 17,
    /// Prefix index reclaimed `a` shared blocks under memory pressure.
    PrefixReclaim = 18,
    /// Shard is dying (emitted immediately before the fatal panic;
    /// `a` = engine iteration).
    ShardDeath = 19,
    /// First output token of `sid` (`a` = TTFT µs, `b` = class).
    FirstToken = 20,
    /// Request `sid` finished (`a` = class, `b` = generated tokens).
    Finish = 21,
    /// Request `sid` aborted by cancellation.
    Abort = 22,
    /// Request `sid` drained to the durable store mid-flight.
    Drain = 23,
    /// Checkpoint repair refetched `a` blocks for `sid` after a torn
    /// write.
    Repair = 24,
    /// Recovery round started replaying a dead shard's work
    /// (`a` = dead shard, `b` = jobs replayed).
    Recover = 25,
}

impl EventKind {
    pub fn from_u8(v: u8) -> Option<EventKind> {
        use EventKind::*;
        Some(match v {
            0 => AdmitOnline,
            1 => ShedOnline,
            2 => JobAccept,
            3 => JobDownTier,
            4 => JobReject,
            5 => QueueEnter,
            6 => PrefillChunk,
            7 => Iteration,
            8 => Preempt,
            9 => LayerAbort,
            10 => StealDemand,
            11 => StealDonate,
            12 => StealAbsorb,
            13 => CkptFlush,
            14 => HarvestTighten,
            15 => HarvestOpen,
            16 => PrefixAttach,
            17 => PrefixPublish,
            18 => PrefixReclaim,
            19 => ShardDeath,
            20 => FirstToken,
            21 => Finish,
            22 => Abort,
            23 => Drain,
            24 => Repair,
            25 => Recover,
            _ => return None,
        })
    }

    /// Stable wire name (flight dumps, Perfetto event names).
    pub fn name(self) -> &'static str {
        use EventKind::*;
        match self {
            AdmitOnline => "admit_online",
            ShedOnline => "shed_online",
            JobAccept => "job_accept",
            JobDownTier => "job_down_tier",
            JobReject => "job_reject",
            QueueEnter => "queue_enter",
            PrefillChunk => "prefill_chunk",
            Iteration => "iteration",
            Preempt => "preempt",
            LayerAbort => "layer_abort",
            StealDemand => "steal_demand",
            StealDonate => "steal_donate",
            StealAbsorb => "steal_absorb",
            CkptFlush => "ckpt_flush",
            HarvestTighten => "harvest_tighten",
            HarvestOpen => "harvest_open",
            PrefixAttach => "prefix_attach",
            PrefixPublish => "prefix_publish",
            PrefixReclaim => "prefix_reclaim",
            ShardDeath => "shard_death",
            FirstToken => "first_token",
            Finish => "finish",
            Abort => "abort",
            Drain => "drain",
            Repair => "repair",
            Recover => "recover",
        }
    }

    pub fn from_name(name: &str) -> Option<EventKind> {
        (0..=25u8)
            .filter_map(EventKind::from_u8)
            .find(|k| k.name() == name)
    }

    /// Kinds that end a request span (see [`analyze_spans`]).
    pub fn is_terminal(self) -> bool {
        matches!(self, EventKind::Finish | EventKind::Abort | EventKind::Drain)
    }
}

/// A decoded trace event. `sid` is the submission id
/// ([`crate::request::Request::submitted_id`], the stable cross-shard
/// key) when the event concerns one request, else 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    pub t_us: TimeUs,
    /// Ring index this event was recorded on (engine shard, or the
    /// front-door track for admission verdicts).
    pub shard: u32,
    pub kind: EventKind,
    pub sid: u64,
    pub a: u64,
    pub b: u64,
}

impl TraceEvent {
    /// One JSONL flight-dump line (deterministic key order).
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("a", num(self.a as f64)),
            ("b", num(self.b as f64)),
            ("kind", Json::Str(self.kind.name().to_string())),
            ("shard", num(self.shard as f64)),
            ("sid", num(self.sid as f64)),
            ("t_us", num(self.t_us as f64)),
        ])
    }

    /// Parse one flight-dump line back into an event.
    pub fn from_json(j: &Json) -> Option<TraceEvent> {
        let kind = EventKind::from_name(j.get("kind")?.as_str()?)?;
        Some(TraceEvent {
            t_us: j.get("t_us")?.as_f64()? as TimeUs,
            shard: j.get("shard")?.as_f64()? as u32,
            kind,
            sid: j.get("sid")?.as_f64()? as u64,
            a: j.get("a")?.as_f64()? as u64,
            b: j.get("b")?.as_f64()? as u64,
        })
    }
}

/// One shard's event ring. Single logical producer (the engine thread);
/// any number of concurrent snapshot readers.
pub struct ShardTracer {
    shard: u32,
    cap: usize,
    /// Total events ever emitted; slot = (seq % cap) * 5.
    cursor: AtomicU64,
    words: Box<[AtomicU64]>,
}

impl ShardTracer {
    pub fn new(shard: usize, cap: usize) -> Self {
        let cap = cap.max(16);
        let mut v = Vec::with_capacity(cap * WORDS_PER_EVENT);
        v.resize_with(cap * WORDS_PER_EVENT, || AtomicU64::new(u64::MAX));
        Self {
            shard: shard as u32,
            cap,
            cursor: AtomicU64::new(0),
            words: v.into_boxed_slice(),
        }
    }

    #[inline]
    pub fn shard(&self) -> u32 {
        self.shard
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Record one event. Lock-free, allocation-free: one `fetch_add` and
    /// five relaxed stores.
    #[inline]
    pub fn emit(&self, t: TimeUs, kind: EventKind, sid: u64, a: u64, b: u64) {
        let seq = self.cursor.fetch_add(1, Ordering::Relaxed);
        let base = (seq as usize % self.cap) * WORDS_PER_EVENT;
        self.words[base].store(t, Ordering::Relaxed);
        self.words[base + 1].store(
            kind as u64 | ((self.shard as u64) << 8),
            Ordering::Relaxed,
        );
        self.words[base + 2].store(sid, Ordering::Relaxed);
        self.words[base + 3].store(a, Ordering::Relaxed);
        self.words[base + 4].store(b, Ordering::Release);
    }

    /// Total events ever emitted (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.cursor.load(Ordering::Acquire)
    }

    /// Events lost to ring overwrite.
    pub fn dropped(&self) -> u64 {
        self.total().saturating_sub(self.cap as u64)
    }

    /// Decode the surviving events, oldest first. Raced against a live
    /// producer this can skip a torn latest slot (invalid kind byte) or
    /// include an event overwritten mid-read; taken after the producer
    /// joined it is exact and in emission order.
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let total = self.cursor.load(Ordering::Acquire);
        let n = (total as usize).min(self.cap);
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let seq = total as usize - n + i;
            let base = (seq % self.cap) * WORDS_PER_EVENT;
            let w1 = self.words[base + 1].load(Ordering::Relaxed);
            let Some(kind) = EventKind::from_u8((w1 & 0xff) as u8) else {
                continue; // unwritten or torn slot
            };
            out.push(TraceEvent {
                t_us: self.words[base].load(Ordering::Relaxed),
                shard: ((w1 >> 8) & 0xffff_ffff) as u32,
                kind,
                sid: self.words[base + 2].load(Ordering::Relaxed),
                a: self.words[base + 3].load(Ordering::Relaxed),
                b: self.words[base + 4].load(Ordering::Relaxed),
            });
        }
        out
    }
}

impl fmt::Debug for ShardTracer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ShardTracer")
            .field("shard", &self.shard)
            .field("cap", &self.cap)
            .field("total", &self.total())
            .finish()
    }
}

/// The fleet's rings: one per engine shard, plus an optional extra
/// track for front-door (admission) events so HTTP handler threads
/// never share an engine's single-producer ring.
pub struct FleetTracer {
    cells: Vec<Arc<ShardTracer>>,
    /// Index of the front-door track, if present (always the last).
    front: Option<usize>,
}

impl FleetTracer {
    /// `n_shards` engine tracks, no front-door track (sim / jobs).
    pub fn new(n_shards: usize, cap: usize) -> Arc<Self> {
        Arc::new(Self {
            cells: (0..n_shards)
                .map(|s| Arc::new(ShardTracer::new(s, cap)))
                .collect(),
            front: None,
        })
    }

    /// `n_shards` engine tracks plus a front-door track (serve).
    pub fn with_front(n_shards: usize, cap: usize) -> Arc<Self> {
        Arc::new(Self {
            cells: (0..=n_shards)
                .map(|s| Arc::new(ShardTracer::new(s, cap)))
                .collect(),
            front: Some(n_shards),
        })
    }

    /// Engine shard count (excludes the front-door track).
    pub fn n_shards(&self) -> usize {
        self.front.unwrap_or(self.cells.len())
    }

    pub fn n_tracks(&self) -> usize {
        self.cells.len()
    }

    pub fn shard(&self, i: usize) -> Arc<ShardTracer> {
        self.cells[i].clone()
    }

    pub fn front(&self) -> Option<Arc<ShardTracer>> {
        self.front.map(|i| self.cells[i].clone())
    }

    pub fn track_name(&self, i: usize) -> String {
        if Some(i) == self.front {
            "front-door".to_string()
        } else {
            format!("shard {i}")
        }
    }

    pub fn total_events(&self) -> u64 {
        self.cells.iter().map(|c| c.total()).sum()
    }

    pub fn dropped(&self) -> u64 {
        self.cells.iter().map(|c| c.dropped()).sum()
    }

    /// Per-track snapshots, oldest-first within each track.
    pub fn snapshot_all(&self) -> Vec<Vec<TraceEvent>> {
        self.cells.iter().map(|c| c.snapshot()).collect()
    }

    /// All surviving events flattened and deterministically ordered
    /// (time, then track, then per-track emission order).
    pub fn merged(&self) -> Vec<TraceEvent> {
        let mut all: Vec<(u64, u32, usize, TraceEvent)> = Vec::new();
        for (track, evs) in self.snapshot_all().into_iter().enumerate() {
            for (idx, e) in evs.into_iter().enumerate() {
                all.push((e.t_us, track as u32, idx, e));
            }
        }
        all.sort_by_key(|(t, track, idx, _)| (*t, *track, *idx));
        all.into_iter().map(|(_, _, _, e)| e).collect()
    }
}

impl fmt::Debug for FleetTracer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "FleetTracer {{ tracks: {}, events: {} }}",
            self.cells.len(),
            self.total_events()
        )
    }
}

/// Post-mortem flight-recorder dump: write the last `last_n` events of
/// every track to `<dir>/flight-<tag>.jsonl` (one JSON object per line,
/// tracks concatenated in order, oldest first within a track). Returns
/// the path written.
pub fn flight_dump(
    dir: &Path,
    tag: &str,
    fleet: &FleetTracer,
    last_n: usize,
) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("flight-{tag}.jsonl"));
    let mut out = String::new();
    for evs in fleet.snapshot_all() {
        let start = evs.len().saturating_sub(last_n);
        for e in &evs[start..] {
            out.push_str(&e.to_json().to_string());
            out.push('\n');
        }
    }
    std::fs::write(&path, out)?;
    Ok(path)
}

/// Parse a flight dump back into events (bad lines are skipped).
pub fn parse_flight_dump(text: &str) -> Vec<TraceEvent> {
    text.lines()
        .filter_map(|l| Json::parse(l).ok())
        .filter_map(|j| TraceEvent::from_json(&j))
        .collect()
}

/// Span well-formedness report (see [`analyze_spans`]).
#[derive(Debug, Default)]
pub struct SpanReport {
    /// Distinct request sids observed.
    pub spans: usize,
    /// Spans that reached a terminal event (finish/abort/drain).
    pub finished: usize,
    /// Spans excused by a shard death (killed mid-flight, no terminal).
    pub killed: usize,
    /// Sids that violate well-formedness.
    pub orphans: Vec<u64>,
}

impl SpanReport {
    pub fn ok(&self) -> bool {
        self.orphans.is_empty()
    }
}

/// Check that every request span is well-formed: a span (all events
/// sharing a nonzero `sid`) must open with a queue entry and close with
/// a terminal event (finish, abort, or drain). A span without a
/// terminal is excused only if a shard that touched it died
/// (`dead_shards`) or `allow_open` is set (run truncated mid-flight).
/// A terminal without a queue entry is an orphan unless `had_drops`
/// (the opening event may have been overwritten).
///
/// Spans are grouped by sid across shards, so a request that migrates
/// (donate on one shard, absorb + finish on another) or is replayed by
/// crash recovery under the same sid forms one span.
pub fn analyze_spans(
    events: &[TraceEvent],
    dead_shards: &[u32],
    allow_open: bool,
    had_drops: bool,
) -> SpanReport {
    use std::collections::BTreeMap;
    #[derive(Default)]
    struct Span {
        entered: bool,
        terminal: bool,
        touched_dead: bool,
    }
    let mut spans: BTreeMap<u64, Span> = BTreeMap::new();
    for e in events {
        if e.sid == 0 {
            continue;
        }
        // Only request-lifecycle kinds participate; front-door admits
        // precede queue entry and never require one.
        let relevant = matches!(
            e.kind,
            EventKind::QueueEnter
                | EventKind::PrefillChunk
                | EventKind::FirstToken
                | EventKind::Preempt
                | EventKind::StealDonate
                | EventKind::StealAbsorb
                | EventKind::Repair
                | EventKind::Finish
                | EventKind::Abort
                | EventKind::Drain
        );
        if !relevant {
            continue;
        }
        let s = spans.entry(e.sid).or_default();
        if e.kind == EventKind::QueueEnter {
            s.entered = true;
        }
        if e.kind.is_terminal() {
            s.terminal = true;
        }
        if dead_shards.contains(&e.shard) {
            s.touched_dead = true;
        }
    }
    let mut rep = SpanReport::default();
    for (sid, s) in &spans {
        rep.spans += 1;
        if s.terminal {
            rep.finished += 1;
            if !s.entered && !had_drops {
                rep.orphans.push(*sid);
            }
        } else if s.touched_dead {
            rep.killed += 1;
        } else if !allow_open {
            rep.orphans.push(*sid);
        }
    }
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrip_and_names_unique() {
        let mut names = std::collections::HashSet::new();
        for v in 0..=25u8 {
            let k = EventKind::from_u8(v).expect("contiguous kinds");
            assert_eq!(k as u8, v);
            assert!(names.insert(k.name()), "duplicate name {}", k.name());
            assert_eq!(EventKind::from_name(k.name()), Some(k));
        }
        assert_eq!(EventKind::from_u8(26), None);
    }

    #[test]
    fn ring_records_and_wraps() {
        let tr = ShardTracer::new(3, 16);
        for i in 0..40u64 {
            tr.emit(i * 10, EventKind::Iteration, i, i * 2, i * 3);
        }
        assert_eq!(tr.total(), 40);
        assert_eq!(tr.dropped(), 24);
        let evs = tr.snapshot();
        assert_eq!(evs.len(), 16);
        // oldest surviving event is seq 24
        assert_eq!(evs[0].sid, 24);
        assert_eq!(evs[15].sid, 39);
        for (i, e) in evs.iter().enumerate() {
            let seq = 24 + i as u64;
            assert_eq!(e.t_us, seq * 10);
            assert_eq!(e.shard, 3);
            assert_eq!(e.kind, EventKind::Iteration);
            assert_eq!((e.a, e.b), (seq * 2, seq * 3));
        }
    }

    #[test]
    fn snapshot_skips_unwritten_slots() {
        let tr = ShardTracer::new(0, 16);
        assert!(tr.snapshot().is_empty());
        tr.emit(5, EventKind::Finish, 7, 1, 0);
        let evs = tr.snapshot();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].kind, EventKind::Finish);
    }

    #[test]
    fn event_json_roundtrip() {
        let e = TraceEvent {
            t_us: 123_456,
            shard: 2,
            kind: EventKind::StealDonate,
            sid: 99,
            a: 1,
            b: 640,
        };
        let j = e.to_json();
        let back = TraceEvent::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back, e);
    }

    #[test]
    fn flight_dump_roundtrip_and_tail() {
        let fleet = FleetTracer::new(2, 64);
        for i in 0..10u64 {
            fleet.shard(0).emit(i, EventKind::Iteration, 0, i, 0);
        }
        fleet.shard(1).emit(99, EventKind::ShardDeath, 0, 42, 0);
        let dir = std::env::temp_dir().join("conserve-trace-test-dump");
        let path = flight_dump(&dir, "t0", &fleet, 4).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let evs = parse_flight_dump(&text);
        // last 4 of shard 0 + the single shard-1 event
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].a, 6, "dump keeps only the tail");
        let last = evs.last().unwrap();
        assert_eq!(last.kind, EventKind::ShardDeath);
        assert_eq!(last.a, 42);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merged_orders_by_time_then_track() {
        let fleet = FleetTracer::with_front(2, 64);
        fleet.shard(1).emit(20, EventKind::Finish, 5, 0, 0);
        fleet.shard(0).emit(10, EventKind::QueueEnter, 5, 0, 16);
        fleet.front().unwrap().emit(10, EventKind::AdmitOnline, 5, 16, 0);
        let m = fleet.merged();
        assert_eq!(m.len(), 3);
        assert_eq!(m[0].kind, EventKind::QueueEnter, "shard 0 before front at t=10");
        assert_eq!(m[1].kind, EventKind::AdmitOnline);
        assert_eq!(m[2].kind, EventKind::Finish);
        assert_eq!(fleet.n_shards(), 2);
        assert_eq!(fleet.n_tracks(), 3);
        assert_eq!(fleet.track_name(2), "front-door");
    }

    #[test]
    fn span_analysis_flags_orphans_and_excuses_deaths() {
        let ev = |kind, shard, sid| TraceEvent {
            t_us: 0,
            shard,
            kind,
            sid,
            a: 0,
            b: 0,
        };
        // sid 1: clean; sid 2: open on a dead shard; sid 3: open on a
        // live shard (orphan); sid 4: terminal with no entry (orphan
        // when nothing was dropped); sid 5: migrated then finished.
        let events = vec![
            ev(EventKind::QueueEnter, 0, 1),
            ev(EventKind::Finish, 0, 1),
            ev(EventKind::QueueEnter, 1, 2),
            ev(EventKind::QueueEnter, 0, 3),
            ev(EventKind::Finish, 0, 4),
            ev(EventKind::QueueEnter, 0, 5),
            ev(EventKind::StealDonate, 0, 5),
            ev(EventKind::StealAbsorb, 1, 5),
            ev(EventKind::Finish, 1, 5),
        ];
        let rep = analyze_spans(&events, &[1], false, false);
        assert_eq!(rep.spans, 5);
        assert_eq!(rep.finished, 3);
        assert_eq!(rep.killed, 1);
        assert_eq!(rep.orphans, vec![3, 4]);
        assert!(!rep.ok());
        // drops excuse the missing entry; allow_open excuses sid 3
        let rep = analyze_spans(&events, &[1], true, true);
        assert!(rep.ok(), "orphans: {:?}", rep.orphans);
    }

    #[test]
    fn concurrent_snapshot_is_safe() {
        let tr = Arc::new(ShardTracer::new(0, 128));
        let wtr = tr.clone();
        let w = std::thread::spawn(move || {
            for i in 0..20_000u64 {
                wtr.emit(i, EventKind::Iteration, i, i, i);
            }
        });
        for _ in 0..50 {
            let evs = tr.snapshot();
            assert!(evs.len() <= 128);
        }
        w.join().unwrap();
        assert_eq!(tr.total(), 20_000);
        assert_eq!(tr.snapshot().len(), 128);
    }
}
