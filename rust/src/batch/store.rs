//! Durable, resumable job store: three append-only JSONL files under a
//! `--state-dir`.
//!
//! * `specs.jsonl` — one line per admitted job: the [`JobSpec`] plus
//!   every request descriptor (submission id, prompt, lengths). Written
//!   once, at admission.
//! * `checkpoints.jsonl` — cold [`PortableRequest`] snapshots of
//!   requests still unfinished when the process stops (graceful drain
//!   or crash-time persistence). Appended; the **last** line per
//!   submission id wins.
//! * `outputs.jsonl` — completed request outputs (submission id, job,
//!   token stream). Appended as requests complete or at run end.
//!
//! Resume protocol (`--resume`): [`JobStore::load`] replays all three
//! files into a [`ResumeState`]; for every stored request, an output
//! line means *done* (skip), else the newest checkpoint (outputs so
//! far + sampler state; prefill recomputes) or, failing that, the spec
//! descriptor recreates the request **with its original submission
//! id** — so the derived sampler state, and therefore the keyed token
//! stream, is byte-identical to an uninterrupted run (asserted by
//! `tests/job_store_props.rs`).
//!
//! Torn writes: a process can die mid-line, so each file tolerates an
//! unparseable **final** line (it is ignored — that record simply never
//! durably happened). For `specs.jsonl` and `outputs.jsonl`, garbage in
//! the *middle* of a file is real corruption and fails the load: those
//! records exist nowhere else. `checkpoints.jsonl` reads leniently
//! instead — any unparseable line is skipped and counted
//! ([`ResumeState::torn_checkpoint_lines`]) — because checkpoints are
//! redundant by construction: an older checkpoint or the spec line
//! always covers the same request, so a line garbled by a crash
//! mid-append (which a later append can merge into) costs bounded
//! decode progress, never recoverability.

use super::{FinishedOutput, JobSpec};
use crate::request::{json_f64, json_u64_str, tok_arr, tok_vec, PortableRequest, Request, TokenId};
use crate::util::json::{arr, num, obj, Json};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write as _};
use std::path::{Path, PathBuf};

/// One request descriptor as persisted in a spec line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoredRequest {
    pub sid: u64,
    pub prompt: Vec<TokenId>,
    pub prompt_len: usize,
    pub max_new_tokens: usize,
}

/// One persisted job: its spec and request descriptors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoredJob {
    pub spec: JobSpec,
    pub requests: Vec<StoredRequest>,
}

/// Everything a restart can recover (see the module docs for how the
/// three maps compose into the replay set).
#[derive(Debug, Default)]
pub struct ResumeState {
    /// Jobs in spec-line order.
    pub jobs: Vec<StoredJob>,
    /// Completed outputs by submission id (last line wins).
    pub outputs: BTreeMap<u64, FinishedOutput>,
    /// Newest cold checkpoint by submission id (last line wins).
    pub checkpoints: BTreeMap<u64, PortableRequest>,
    /// Unparseable lines skipped while reading `checkpoints.jsonl`
    /// (torn writes and the appends that merged into them). Nonzero
    /// means recovery fell back past some newest-checkpoint state.
    pub torn_checkpoint_lines: usize,
}

/// Append-side handle. One writer per state dir; every record is one
/// `write_all` of a full line followed by a flush, so the only torn
/// write a crash can produce is a partial final line — exactly what
/// [`JobStore::load`] tolerates.
pub struct JobStore {
    dir: PathBuf,
    specs: BufWriter<File>,
    checkpoints: BufWriter<File>,
    outputs: BufWriter<File>,
}

const SPECS: &str = "specs.jsonl";
const CHECKPOINTS: &str = "checkpoints.jsonl";
const OUTPUTS: &str = "outputs.jsonl";

impl JobStore {
    /// Open (creating the directory and files as needed) for appending.
    /// A torn final line left by a crash is truncated away first —
    /// appending after it would otherwise merge the next record into
    /// the fragment, turning a tolerated torn tail into mid-file
    /// corruption that fails every later load.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating state dir {}", dir.display()))?;
        let appender = |name: &str| -> Result<BufWriter<File>> {
            let path = dir.join(name);
            heal_torn_tail(&path)?;
            let f = OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("opening {}", path.display()))?;
            Ok(BufWriter::new(f))
        };
        Ok(Self {
            specs: appender(SPECS)?,
            checkpoints: appender(CHECKPOINTS)?,
            outputs: appender(OUTPUTS)?,
            dir,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Persist an admitted job: its spec plus the stamped requests
    /// (their submission ids and prompts are what resume replays from).
    pub fn record_spec(&mut self, spec: &JobSpec, requests: &[Request]) -> Result<()> {
        let line = obj(vec![
            ("job", num(spec.job as f64)),
            ("tenant", num(spec.tenant as f64)),
            ("tier", num(spec.tier as f64)),
            ("deadline", num(spec.deadline as f64)),
            ("submitted_at", num(spec.submitted_at as f64)),
            ("n_requests", num(spec.n_requests as f64)),
            ("total_tokens", num(spec.total_tokens as f64)),
            (
                "requests",
                arr(requests.iter().filter(|r| r.job == spec.job).map(|r| {
                    obj(vec![
                        ("sid", Json::Str(r.submitted_id.to_string())),
                        ("prompt", tok_arr(&r.prompt)),
                        ("prompt_len", num(r.prompt_len as f64)),
                        ("max_new", num(r.max_new_tokens as f64)),
                    ])
                })),
            ),
        ]);
        write_line(&mut self.specs, &line)
    }

    /// Persist a cold checkpoint of an unfinished request (newest line
    /// per sid wins on load).
    pub fn record_checkpoint(&mut self, p: &PortableRequest) -> Result<()> {
        let line = p.to_json();
        write_line(&mut self.checkpoints, &line)
    }

    /// Fault-injection hook (`torn-ckpt`, see [`crate::util::fault`]):
    /// write `p`'s checkpoint record torn mid-line — a prefix of the
    /// JSON with no terminating newline, flushed — modeling a crash (or
    /// partial sector write) mid-append. Recovery skips the garbled
    /// line (lenient checkpoint read) and falls back to the previous
    /// checkpoint or the spec.
    pub fn record_checkpoint_torn(&mut self, p: &PortableRequest) -> Result<()> {
        let s = p.to_json().to_string();
        let cut = (s.len() * 2 / 3).max(1);
        self.checkpoints
            .write_all(&s.as_bytes()[..cut])
            .context("job store write (torn)")?;
        self.checkpoints.flush().context("job store flush")?;
        Ok(())
    }

    /// Persist a completed request's output stream.
    pub fn record_output(&mut self, f: &FinishedOutput) -> Result<()> {
        let line = obj(vec![
            ("sid", Json::Str(f.sid.to_string())),
            ("job", num(f.job as f64)),
            ("generated", num(f.generated as f64)),
            ("output", tok_arr(&f.output)),
        ]);
        write_line(&mut self.outputs, &line)
    }

    /// Read a state dir back (missing files = empty state). Tolerates a
    /// truncated final line per file; rejects mid-file garbage.
    pub fn load(dir: impl AsRef<Path>) -> Result<ResumeState> {
        let dir = dir.as_ref();
        let mut state = ResumeState::default();
        for line in read_jsonl(&dir.join(SPECS))? {
            state.jobs.push(parse_spec_line(&line)?);
        }
        let (ckpt_lines, torn) = read_jsonl_lenient(&dir.join(CHECKPOINTS))?;
        state.torn_checkpoint_lines = torn;
        for line in ckpt_lines {
            let p = PortableRequest::from_json(&line)?;
            state.checkpoints.insert(p.submitted_id, p);
        }
        for line in read_jsonl(&dir.join(OUTPUTS))? {
            let f = parse_output_line(&line)?;
            state.outputs.insert(f.sid, f);
        }
        Ok(state)
    }
}

/// Truncate a torn (newline-less) final line before appending. The
/// dropped fragment never durably happened — `load` was already
/// ignoring it — but a record appended after it would merge into one
/// unparseable line and corrupt the file for every later load.
fn heal_torn_tail(path: &Path) -> Result<()> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(()),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    if bytes.is_empty() || bytes.ends_with(b"\n") {
        return Ok(());
    }
    let keep = bytes.iter().rposition(|&b| b == b'\n').map_or(0, |i| i + 1);
    eprintln!(
        "[job-store] {}: truncating torn final line ({} bytes) before appending",
        path.display(),
        bytes.len() - keep
    );
    let f = OpenOptions::new()
        .write(true)
        .open(path)
        .with_context(|| format!("healing {}", path.display()))?;
    f.set_len(keep as u64)
        .with_context(|| format!("truncating {}", path.display()))?;
    Ok(())
}

fn write_line(w: &mut BufWriter<File>, line: &Json) -> Result<()> {
    let mut s = line.to_string();
    s.push('\n');
    w.write_all(s.as_bytes()).context("job store write")?;
    w.flush().context("job store flush")?;
    Ok(())
}

/// Parse a JSONL file, ignoring an unparseable final line (torn write).
fn read_jsonl(path: &Path) -> Result<Vec<Json>> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    let lines: Vec<&str> = text
        .lines()
        .map(str::trim)
        .filter(|l| !l.is_empty())
        .collect();
    let mut out = Vec::with_capacity(lines.len());
    for (i, line) in lines.iter().enumerate() {
        match Json::parse(line) {
            Ok(v) => out.push(v),
            Err(e) if i + 1 == lines.len() => {
                // torn final line: the record never durably happened
                eprintln!(
                    "[job-store] {}: ignoring truncated final line ({e})",
                    path.display()
                );
            }
            Err(e) => bail!("{}: corrupt line {}: {e}", path.display(), i + 1),
        }
    }
    Ok(out)
}

/// Parse a JSONL file leniently: unparseable lines anywhere are skipped
/// and counted instead of failing the load. Only the checkpoint file
/// reads this way — see the module docs for why that is safe there and
/// nowhere else.
fn read_jsonl_lenient(path: &Path) -> Result<(Vec<Json>, usize)> {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok((Vec::new(), 0)),
        Err(e) => return Err(e).with_context(|| format!("reading {}", path.display())),
    };
    let mut out = Vec::new();
    let mut skipped = 0usize;
    for (i, line) in text.lines().map(str::trim).filter(|l| !l.is_empty()).enumerate() {
        match Json::parse(line) {
            Ok(v) => out.push(v),
            Err(e) => {
                skipped += 1;
                eprintln!(
                    "[job-store] {}: skipping unparseable checkpoint line {} ({e})",
                    path.display(),
                    i + 1
                );
            }
        }
    }
    Ok((out, skipped))
}

fn parse_spec_line(j: &Json) -> Result<StoredJob> {
    const WHAT: &str = "spec line";
    let f = |k: &str| json_f64(j, WHAT, k);
    let spec = JobSpec {
        job: f("job")? as u64,
        tenant: f("tenant")? as u32,
        tier: f("tier")? as u8,
        deadline: f("deadline")? as u64,
        submitted_at: f("submitted_at")? as u64,
        n_requests: f("n_requests")? as u64,
        total_tokens: f("total_tokens")? as u64,
    };
    let mut requests = Vec::new();
    let Some(reqs) = j.get("requests").and_then(Json::as_arr) else {
        bail!("spec line: missing requests array");
    };
    for r in reqs {
        requests.push(StoredRequest {
            sid: json_u64_str(r, WHAT, "sid")?,
            prompt: tok_vec(r.get("prompt"), WHAT)?,
            prompt_len: json_f64(r, WHAT, "prompt_len")? as usize,
            max_new_tokens: json_f64(r, WHAT, "max_new")? as usize,
        });
    }
    Ok(StoredJob { spec, requests })
}

fn parse_output_line(j: &Json) -> Result<FinishedOutput> {
    const WHAT: &str = "output line";
    Ok(FinishedOutput {
        sid: json_u64_str(j, WHAT, "sid")?,
        job: json_f64(j, WHAT, "job")? as u64,
        generated: json_f64(j, WHAT, "generated")? as u64,
        output: tok_vec(j.get("output"), WHAT)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batch::{JobInput, JobManager, JobRequest};

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "conserve-store-{tag}-{}-{}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn spec_checkpoint_output_round_trip() {
        let dir = tmp_dir("rt");
        let mut jm = JobManager::new(5_000.0);
        let mut reqs = Vec::new();
        let spec = jm.admit(
            &JobInput {
                tenant: 3,
                tier: 1,
                submitted_at: 42,
                deadline: 9_000_000,
                requests: vec![
                    JobRequest {
                        prompt: vec![1, 2, 3],
                        prompt_len: 3,
                        max_new_tokens: 5,
                    },
                    JobRequest {
                        prompt: Vec::new(),
                        prompt_len: 64,
                        max_new_tokens: 8,
                    },
                ],
            },
            &mut reqs,
        );
        {
            let mut store = JobStore::open(&dir).unwrap();
            store.record_spec(&spec, &reqs).unwrap();
            let p = PortableRequest::snapshot_cold(&reqs[0]);
            store.record_checkpoint(&p).unwrap();
            store
                .record_output(&FinishedOutput {
                    sid: reqs[1].submitted_id,
                    job: spec.job,
                    generated: 8,
                    output: vec![7; 8],
                })
                .unwrap();
        }
        let state = JobStore::load(&dir).unwrap();
        assert_eq!(state.jobs.len(), 1);
        assert_eq!(state.jobs[0].spec, spec);
        assert_eq!(state.jobs[0].requests.len(), 2);
        assert_eq!(state.jobs[0].requests[0].prompt, vec![1, 2, 3]);
        assert_eq!(state.checkpoints.len(), 1);
        assert!(state.checkpoints.contains_key(&reqs[0].submitted_id));
        assert_eq!(state.outputs[&reqs[1].submitted_id].output, vec![7; 8]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_final_line_is_tolerated_mid_file_garbage_is_not() {
        let dir = tmp_dir("torn");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join(OUTPUTS),
            "{\"sid\":\"1\",\"job\":1,\"generated\":1,\"output\":[9]}\n{\"sid\":\"2\",\"job\":1,\"gen",
        )
        .unwrap();
        let state = JobStore::load(&dir).unwrap();
        assert_eq!(state.outputs.len(), 1, "torn tail ignored");
        assert!(state.outputs.contains_key(&1));

        std::fs::write(
            dir.join(OUTPUTS),
            "garbage\n{\"sid\":\"1\",\"job\":1,\"generated\":1,\"output\":[9]}\n",
        )
        .unwrap();
        assert!(JobStore::load(&dir).is_err(), "mid-file corruption fails");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reopening_after_torn_write_heals_the_tail() {
        // crash run 1 mid-append, resume run 2 appends a record, run 3
        // loads: the torn fragment must not merge with run 2's record
        let dir = tmp_dir("heal");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join(OUTPUTS),
            "{\"sid\":\"1\",\"job\":1,\"generated\":1,\"output\":[9]}\n{\"sid\":\"2\",\"job\":1,\"gen",
        )
        .unwrap();
        {
            let mut store = JobStore::open(&dir).unwrap();
            store
                .record_output(&FinishedOutput {
                    sid: 3,
                    job: 1,
                    generated: 2,
                    output: vec![5, 6],
                })
                .unwrap();
        }
        let state = JobStore::load(&dir).unwrap();
        assert_eq!(state.outputs.len(), 2, "torn tail healed, new record intact");
        assert!(state.outputs.contains_key(&1));
        assert_eq!(state.outputs[&3].output, vec![5, 6]);
        assert!(!state.outputs.contains_key(&2), "the torn record is gone");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_mid_run_checkpoint_falls_back_without_failing_the_load() {
        // a torn checkpoint write *mid-run* (process keeps appending
        // afterwards) garbles one mid-file line: the torn fragment and
        // the next append merge. The lenient checkpoint read must skip
        // and count it, and later clean checkpoints must still win.
        let dir = tmp_dir("torn-mid");
        let mut jm = JobManager::new(5_000.0);
        let mut reqs = Vec::new();
        jm.admit(
            &JobInput {
                tenant: 1,
                tier: 2,
                submitted_at: 0,
                deadline: 0,
                requests: vec![JobRequest {
                    prompt: Vec::new(),
                    prompt_len: 32,
                    max_new_tokens: 16,
                }],
            },
            &mut reqs,
        );
        {
            let mut store = JobStore::open(&dir).unwrap();
            let mut r = reqs[0].clone();
            r.generated = 2;
            r.output = vec![1, 2];
            store.record_checkpoint(&PortableRequest::snapshot_cold(&r)).unwrap();
            r.generated = 3;
            r.output = vec![1, 2, 3];
            store.record_checkpoint_torn(&PortableRequest::snapshot_cold(&r)).unwrap();
            // this append merges into the torn fragment -> one garbled line
            r.generated = 5;
            r.output = vec![1, 2, 3, 4, 5];
            store.record_checkpoint(&PortableRequest::snapshot_cold(&r)).unwrap();
            // and a later clean line still wins
            r.generated = 7;
            r.output = vec![1, 2, 3, 4, 5, 6, 7];
            store.record_checkpoint(&PortableRequest::snapshot_cold(&r)).unwrap();
        }
        let state = JobStore::load(&dir).unwrap();
        assert_eq!(state.torn_checkpoint_lines, 1, "garbled merged line counted");
        let p = &state.checkpoints[&reqs[0].submitted_id];
        assert_eq!(p.generated, 7, "clean checkpoint after the tear wins");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_loads_empty() {
        let dir = tmp_dir("none");
        let state = JobStore::load(&dir).unwrap();
        assert!(state.jobs.is_empty());
        assert!(state.outputs.is_empty());
        assert!(state.checkpoints.is_empty());
    }

    #[test]
    fn newest_checkpoint_wins() {
        let dir = tmp_dir("newest");
        let mut jm = JobManager::new(5_000.0);
        let mut reqs = Vec::new();
        jm.admit(
            &JobInput {
                tenant: 1,
                tier: 2,
                submitted_at: 0,
                deadline: 0,
                requests: vec![JobRequest {
                    prompt: Vec::new(),
                    prompt_len: 32,
                    max_new_tokens: 16,
                }],
            },
            &mut reqs,
        );
        {
            let mut store = JobStore::open(&dir).unwrap();
            let mut r = reqs[0].clone();
            r.generated = 2;
            r.output = vec![1, 2];
            store
                .record_checkpoint(&PortableRequest::snapshot_cold(&r))
                .unwrap();
            r.generated = 5;
            r.output = vec![1, 2, 3, 4, 5];
            store
                .record_checkpoint(&PortableRequest::snapshot_cold(&r))
                .unwrap();
        }
        let state = JobStore::load(&dir).unwrap();
        let p = &state.checkpoints[&reqs[0].submitted_id];
        assert_eq!(p.generated, 5, "last checkpoint line wins");
        assert_eq!(p.output.len(), 5);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
