//! Request-length datasets and prompt synthesis.
//!
//! * Online requests follow the paper's representative values (input
//!   1024 / output 128, §6.3) with optional jitter.
//! * Offline requests follow a LongBench-like document-summarization
//!   distribution (§6.1): long inputs (1k–8k tokens, log-uniform-ish)
//!   with short-to-medium outputs.
//! * The real tiny-model path scales lengths down to its 256-slot cache
//!   and synthesizes actual byte-level prompt text.

use crate::request::TokenId;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LengthSample {
    pub input: usize,
    pub output: usize,
}

/// Length distribution presets.
#[derive(Debug, Clone, Copy)]
pub enum Lengths {
    /// Fixed input/output (ON/OFF experiments use 1024/128).
    Fixed { input: usize, output: usize },
    /// Online chat-like: mean input/output with +-25% uniform jitter.
    OnlineChat { input: usize, output: usize },
    /// Offline LongBench-like summarization: log-uniform input in
    /// [min_input, max_input], output in [64, 512] (scaled presets below).
    OfflineDocs { min_input: usize, max_input: usize, max_output: usize },
}

impl Lengths {
    pub fn sample(&self, rng: &mut Rng) -> LengthSample {
        match *self {
            Lengths::Fixed { input, output } => LengthSample { input, output },
            Lengths::OnlineChat { input, output } => LengthSample {
                input: jitter(rng, input, 0.25),
                output: jitter(rng, output, 0.25),
            },
            Lengths::OfflineDocs {
                min_input,
                max_input,
                max_output,
            } => {
                let lo = (min_input as f64).ln();
                let hi = (max_input as f64).ln();
                let input = (lo + (hi - lo) * rng.f64()).exp() as usize;
                let output = rng.range_usize(max_output / 8, max_output + 1);
                LengthSample {
                    input: input.max(1),
                    output: output.max(1),
                }
            }
        }
    }

    /// Paper-scale presets (A100/7B sim).
    pub fn online_paper() -> Self {
        Lengths::Fixed {
            input: 1024,
            output: 128,
        }
    }

    pub fn offline_paper() -> Self {
        Lengths::OfflineDocs {
            min_input: 1024,
            max_input: 8192,
            max_output: 512,
        }
    }

    /// Tiny-model presets (max_model_len 256).
    pub fn online_tiny() -> Self {
        Lengths::OnlineChat {
            input: 96,
            output: 24,
        }
    }

    pub fn offline_tiny() -> Self {
        Lengths::OfflineDocs {
            min_input: 64,
            max_input: 192,
            max_output: 48,
        }
    }
}

fn jitter(rng: &mut Rng, base: usize, frac: f64) -> usize {
    let lo = (base as f64 * (1.0 - frac)).max(1.0);
    let hi = base as f64 * (1.0 + frac);
    (lo + (hi - lo) * rng.f64()) as usize
}

/// Pseudo-English words for synthesizing real prompts on the byte-level
/// tokenizer path (document-summarization flavor).
const WORDS: &[&str] = &[
    "the", "model", "serves", "online", "requests", "with", "low", "latency",
    "while", "offline", "batch", "jobs", "harvest", "idle", "gpu", "cycles",
    "document", "summary", "section", "reports", "quarterly", "results",
    "system", "throughput", "cache", "memory", "token", "schedule",
];

/// Synthesize a prompt of exactly `n_tokens` byte-level tokens.
pub fn synth_prompt(rng: &mut Rng, n_tokens: usize) -> Vec<TokenId> {
    let mut text = String::new();
    while text.len() < n_tokens {
        if !text.is_empty() {
            text.push(' ');
        }
        text.push_str(WORDS[rng.range_usize(0, WORDS.len())]);
    }
    text.truncate(n_tokens);
    text.into_bytes().into_iter().map(|b| b as TokenId).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_lengths() {
        let mut r = Rng::new(0);
        let l = Lengths::online_paper().sample(&mut r);
        assert_eq!(l, LengthSample { input: 1024, output: 128 });
    }

    #[test]
    fn offline_docs_within_bounds() {
        let mut r = Rng::new(1);
        let d = Lengths::offline_paper();
        for _ in 0..500 {
            let l = d.sample(&mut r);
            assert!((1024..=8192).contains(&l.input), "input={}", l.input);
            assert!((64..=512).contains(&l.output), "output={}", l.output);
        }
    }

    #[test]
    fn offline_docs_log_spread() {
        let mut r = Rng::new(2);
        let d = Lengths::offline_paper();
        let xs: Vec<usize> = (0..2000).map(|_| d.sample(&mut r).input).collect();
        let below_2k = xs.iter().filter(|&&x| x < 2048).count();
        // log-uniform: ~half the mass below geometric midpoint (~2896)
        assert!(below_2k > 500 && below_2k < 1500, "below_2k={below_2k}");
    }

    #[test]
    fn tiny_lengths_fit_cache() {
        let mut r = Rng::new(3);
        for _ in 0..500 {
            let on = Lengths::online_tiny().sample(&mut r);
            let off = Lengths::offline_tiny().sample(&mut r);
            assert!(on.input + on.output <= 256);
            assert!(off.input + off.output <= 256);
        }
    }

    #[test]
    fn synth_prompt_exact_len_and_byte_range() {
        let mut r = Rng::new(4);
        let p = synth_prompt(&mut r, 100);
        assert_eq!(p.len(), 100);
        assert!(p.iter().all(|&t| t < 256));
    }
}
