//! SLO -> per-iteration budget translation (paper §4.5).
//!
//! The scheduler queries the profiler with the latency SLO — TPOT for
//! batches containing decode-phase requests, TTFT otherwise — to get the
//! maximum number of prefill tokens schedulable this iteration, and uses
//! the same bound to cap background swap I/O per iteration.

use crate::config::SloConfig;
use crate::profiler::LatencyProfile;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterBudget {
    /// Additional prefill tokens admitted this iteration.
    pub prefill_tokens: usize,
    /// KV blocks the background swap engine may move per direction this
    /// iteration without stretching the iteration past the SLO.
    pub io_blocks: usize,
}

/// Token budget given the decode composition already committed to this
/// iteration (decodes are continuous-batched and always run).
pub fn token_budget(
    profile: &LatencyProfile,
    slo: &SloConfig,
    decode_seqs: usize,
    ctx_tokens: usize,
) -> usize {
    let budget_ms = if decode_seqs > 0 {
        slo.tpot_ms
    } else {
        slo.ttft_ms
    };
    profile.max_prefill_tokens((budget_ms * 1000.0) as u64, decode_seqs, ctx_tokens)
}

/// I/O block budget: how many block transfers fit inside the estimated
/// iteration time (the transfers overlap compute; bounding them by the
/// iteration keeps the copy stream from outliving its overlap window).
pub fn io_budget(iter_est_us: u64, block_transfer_us: u64, cap: usize) -> usize {
    if block_transfer_us == 0 {
        return cap;
    }
    ((iter_est_us / block_transfer_us) as usize).min(cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> LatencyProfile {
        LatencyProfile {
            c: [1200.0, 96.0, 40.0, 0.385],
        }
    }

    fn slo() -> SloConfig {
        SloConfig {
            ttft_ms: 1500.0,
            tpot_ms: 110.0,
        }
    }

    #[test]
    fn decode_batches_use_tpot() {
        let p = profile();
        let b = token_budget(&p, &slo(), 32, 32 * 1024);
        // 110ms - fixed - decode costs, / 96us => ~1.0k tokens
        assert!(b > 500 && b < 1300, "b={b}");
    }

    #[test]
    fn prefill_only_uses_ttft() {
        let p = profile();
        let b = token_budget(&p, &slo(), 0, 0);
        assert!(b > 10_000, "b={b}"); // 1.5s of prefill budget
    }

    #[test]
    fn heavy_decode_leaves_no_room() {
        let p = profile();
        // enormous decode context: no prefill budget left
        let b = token_budget(&p, &slo(), 256, 256 * 4096);
        assert_eq!(b, 0);
    }

    #[test]
    fn io_budget_scales_with_iteration() {
        assert_eq!(io_budget(100_000, 250, 1000), 400);
        assert_eq!(io_budget(100_000, 250, 64), 64); // capped
        assert_eq!(io_budget(0, 250, 64), 0);
    }
}
